//! Flit-level, cycle-driven interconnection network simulator.
//!
//! Substitute for the CAMINOS simulator the paper uses (§5): an event-driven
//! simulator and a cycle-driven one are equivalent at this abstraction level
//! because every CAMINOS event fires on a cycle edge (see DESIGN.md,
//! Substitution 1). The microarchitecture follows §5 exactly:
//!
//! * 16-flit packets;
//! * input ports with 10-packet FIFOs and 5-packet output queues **per
//!   virtual channel, where the VC count is router-determined**
//!   (`Router::num_vcs`): TERA, MIN and the RINR link-ordering schemes run
//!   VC-less (one VC, the paper's headline claim), UGAL/Valiant/Omni-WAR
//!   use 2, and the §6.5 2D-HyperX routers up to 4 — see the
//!   algorithm→policy table in DESIGN.md and `routing/tables.rs` for where
//!   each policy's VC discipline is compiled;
//! * crossbar with 2× speedup and a random (rotating-priority) allocator;
//! * credit-based flow control;
//! * servers attached through injection/ejection ports serialized at one
//!   flit per cycle.
//!
//! Virtual cut-through timing: a packet becomes routable at the downstream
//! switch as soon as its header arrives (flits stream behind it at link
//! rate), and a buffer slot is occupied from header arrival until the
//! crossbar grant releases it upstream via a credit.
//!
//! # Engine architecture (active-set, flat-buffer, phase-parallel)
//!
//! The per-cycle loop touches only components with work (see DESIGN.md,
//! "Active-set invariants"):
//!
//! * all port FIFOs are fixed-capacity rings in flat [`QueuePool`]s
//!   (structure-of-arrays; zero steady-state allocation);
//! * per-shard `active` / global `active_servers` are dirty worklists — a
//!   switch is listed iff it buffers at least one packet
//!   (`Switch::work > 0`), a server iff its source queue is non-empty;
//!   idle components cost zero;
//! * in-flight events live on overflow-safe hierarchical [`TimingWheel`]s
//!   — one per shard, holding the events destined to that shard's own
//!   switches — so arbitrary `link_latency` values are exact;
//! * switches are partitioned into `cfg.shards` contiguous blocks, each
//!   owned by a [`shard::ShardState`]. Every cycle runs a parallel **pop**
//!   phase (each shard dispatches its own wheel's due events), a parallel
//!   **compute** phase (allocation + transmission), a serial O(shards²)
//!   pointer-swap **exchange**, and a parallel **commit** phase (each
//!   shard drains its inbox rows in ascending source-shard order onto its
//!   own wheel) — N-shard runs are bit-identical to 1-shard runs
//!   (DESIGN.md, "Phase-parallel invariants"). `SimConfig::global_wheel`
//!   opts back into one shard-0-homed wheel with serial pop/commit fan-in
//!   (the A/B fallback — also bit-identical);
//! * when a cycle ends with every shard idle, no server eligible to
//!   inject, and nothing due on the wheel until `t'`, the clock jumps
//!   straight to `t'` (**exact next-event time advance**, `RunOpts::
//!   time_skip`): skipped cycles move nothing and draw no randomness, so
//!   results stay bit-identical to fixed-tick for every router, seed and
//!   shard count (DESIGN.md, "Time-advance and stopping invariants").

pub mod packet;
pub mod queues;
mod shard;
pub mod switch;
pub mod wheel;

pub use packet::{Packet, PacketArena, PacketId, NO_MESSAGE, NO_SWITCH};
pub use queues::QueuePool;
pub use switch::{Switch, SwitchView};
pub use wheel::TimingWheel;

use std::sync::{Arc, RwLock};

use crate::config::{FaultTarget, RebuildStrategy};
use crate::metrics::SimStats;
use crate::routing::tables::{DegradedView, RoutingTables};
use crate::routing::Router;
use crate::topology::{DeadSet, PhysTopology};
use crate::traffic::Workload;
use crate::util::Rng;

use shard::{ComputeCtx, Phase, RouterSlot, ShardState, WorkerPool, SWITCH_RNG_STREAM};

/// Simulator parameters (§5 defaults).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Input buffer capacity, packets per VC (paper: 10).
    pub input_cap_pkts: usize,
    /// Output queue capacity, packets per VC (paper: 5).
    pub output_cap_pkts: usize,
    /// Flits per packet (paper: 16).
    pub pkt_flits: u16,
    /// Link latency in cycles (header fly time). Any value ≥ 1 is exact —
    /// the hierarchical timing wheel has no horizon limit.
    pub link_latency: u64,
    /// Crossbar speedup (paper: 2×).
    pub speedup: u64,
    /// Servers (injection/ejection port pairs) per switch.
    pub servers_per_switch: usize,
    /// Master seed; every stochastic component derives from it.
    pub seed: u64,
    /// Cycles without any flit movement (while packets are live) after
    /// which the run is declared deadlocked. Internally floored to
    /// `4 × (link_latency + pkt_flits)` so long wires (packets legitimately
    /// in flight with nothing else moving) never trip it.
    pub watchdog_cycles: u64,
    /// Compute-phase shards: the switches are split into this many
    /// contiguous blocks, simulated concurrently within each cycle
    /// (clamped to the switch count). Results are **bit-identical for any
    /// value** — see DESIGN.md, "Phase-parallel invariants" — so this is a
    /// pure wall-clock knob. 1 (the default) runs fully inline with no
    /// worker threads.
    pub shards: usize,
    /// Batched compute-phase bodies (default on): gather each switch's
    /// eligible lanes into contiguous scratch, score/commit in tight
    /// passes (`shard::ShardState`, DESIGN.md "Batched hot path"). Results
    /// are **bit-identical** with this on or off — pinned by
    /// `tests/engine.rs` — so it is a pure wall-clock knob
    /// (`batched_compute = false` in an experiment spec selects the scalar
    /// reference path).
    pub batched: bool,
    /// Home every timing-wheel event to shard 0's wheel instead of the
    /// destination shard's (`--global-wheel`): Phase 1 pops and the commit
    /// fan-in then re-serialize on shard 0, which is the pre-sharded-wheel
    /// behavior the shard-scaling bench A/Bs against. Results are
    /// **bit-identical** with this on or off (pinned by
    /// `tests/engine.rs`) — another pure wall-clock knob, and the right
    /// fallback when debugging event-ordering questions with one wheel to
    /// inspect.
    pub global_wheel: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            input_cap_pkts: 10,
            output_cap_pkts: 5,
            pkt_flits: 16,
            link_latency: 1,
            speedup: 2,
            servers_per_switch: 4,
            seed: 1,
            watchdog_cycles: 20_000,
            shards: 1,
            batched: true,
            global_wheel: false,
        }
    }
}

/// Run control.
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Hard cycle limit.
    pub max_cycles: u64,
    /// Cycles before the measurement window opens.
    pub warmup: u64,
    /// Measurement window length (None = measure until the end).
    pub window: Option<u64>,
    /// Stop as soon as the workload is exhausted and the network drained
    /// (fixed generation / application kernels).
    pub stop_when_drained: bool,
    /// Exact next-event time advance (default on): when a cycle ends with
    /// no switch buffering a packet, no server eligible to inject, and the
    /// workload quiescent, jump the clock to the earliest cycle at which
    /// anything can happen instead of ticking empty cycles. Skipped cycles
    /// move nothing and draw no randomness, so `SimStats` are
    /// **bit-identical** with this on or off — it is a pure wall-clock
    /// knob (`--fixed-tick` on the CLI disables it; DESIGN.md,
    /// "Time-advance and stopping invariants").
    pub time_skip: bool,
    /// Statistical early termination: `Some(target)` stops an open-loop
    /// run once the steady-state estimator's relative CI half-width over
    /// delivered-flit throughput *and* latency is at or below `target`
    /// (MSER warmup truncation + batch means, `metrics::steady`). `None`
    /// (the default) keeps the fixed budget, so tier-1 results are
    /// unchanged. The achieved half-width is reported in
    /// `SimStats::achieved_rel_ci`.
    pub stop_rel_ci: Option<f64>,
    /// Accumulate a per-phase wall-time breakdown (wheel pop / compute /
    /// exchange / commit) and report it to stderr when the run ends
    /// (`--phase-timings`). Wall times never enter [`SimStats`] — those
    /// must stay bit-deterministic across machines and shard counts.
    pub phase_timings: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            max_cycles: 1_000_000,
            warmup: 0,
            window: None,
            stop_when_drained: true,
            time_skip: true,
            stop_rel_ci: None,
            phase_timings: false,
        }
    }
}

/// Cumulative per-phase wall time over one run (`RunOpts::phase_timings`):
/// the serial-bottleneck diagnostic for shard-scaling work. Reported to
/// stderr, never part of [`SimStats`].
#[derive(Default)]
struct PhaseTimings {
    /// Phase 1: wheel pops + event dispatch (parallel residue included).
    wheel: std::time::Duration,
    /// Phases 4+5: crossbar allocation + link transmission.
    compute: std::time::Duration,
    /// The serial O(shards²) outbox/inbox pointer swap.
    exchange: std::time::Duration,
    /// Inbox → wheel scheduling + credit application.
    commit: std::time::Duration,
}

/// One entry of the no-forward-progress watchdog's structured report: an
/// input/output port pair holding packets that have not moved for the
/// whole watchdog horizon.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StalledPort {
    pub switch: u32,
    pub port: u32,
    /// Packets buffered in the port's input FIFOs / output queues.
    pub queued_in: u32,
    pub queued_out: u32,
}

/// Simulation failure modes.
#[derive(Debug)]
pub enum SimError {
    Deadlock {
        cycle: u64,
        live: usize,
        idle: u64,
        /// First [`STALLED_REPORT_CAP`] stalled ports in canonical
        /// `(switch, port)` order — the buffer cycle a deadlock traps.
        stalled: Vec<StalledPort>,
    },
    CycleLimit(u64),
}

/// Cap on the structured stalled-port report attached to a deadlock error.
pub const STALLED_REPORT_CAP: usize = 16;

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock {
                cycle,
                live,
                idle,
                stalled,
            } => {
                write!(
                    f,
                    "deadlock detected at cycle {cycle}: {live} packets stalled \
                     (no flit moved for {idle} cycles)"
                )?;
                if !stalled.is_empty() {
                    write!(f, "; stalled ports")?;
                    if stalled.len() >= STALLED_REPORT_CAP {
                        write!(f, " (first {STALLED_REPORT_CAP})")?;
                    }
                    write!(f, ":")?;
                    for (i, p) in stalled.iter().enumerate() {
                        let sep = if i == 0 { ' ' } else { ',' };
                        write!(
                            f,
                            "{sep}sw{}.p{}(in {}/out {})",
                            p.switch, p.port, p.queued_in, p.queued_out
                        )?;
                    }
                }
                Ok(())
            }
            SimError::CycleLimit(limit) => {
                write!(f, "cycle limit {limit} reached before the workload drained")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Events scheduled on the timing wheel. Packets travel **by value**: a
/// transmitting shard frees its arena slot and the receiving shard
/// allocates a fresh one, which keeps every arena shard-private (ids are
/// never observable across shards, so arena layout cannot leak into
/// routing decisions).
enum Event {
    /// Packet header reaches input `(sw, port)` on `vc`.
    Arrive {
        sw: u32,
        port: u32,
        vc: u8,
        pkt: Packet,
    },
    /// Packet tail reaches its destination server.
    Deliver { pkt: Packet },
    /// Scheduled fault transition: entry `idx` of the installed fault
    /// schedule fires. Carried on the wheel so the adaptive time advance
    /// sees pending reconfigurations exactly like packet events (a fully
    /// idle network still wakes on the cycle a link dies or recovers).
    Fault { idx: u32 },
}

/// Routing-table rebuild record from one fault reconfiguration instant.
/// Wall-clock rebuild latency is reported here (and in the `faults` bench)
/// rather than in [`SimStats`], which must stay bit-deterministic across
/// shard counts and host machines.
#[derive(Clone, Debug)]
pub struct RebuildRecord {
    /// Cycle at which the transition batch was applied.
    pub cycle: u64,
    /// `"recompile"` (stop-the-world) or `"patch"` (incremental).
    pub strategy: &'static str,
    /// Wall-clock table rebuild time, microseconds.
    pub micros: u64,
    /// Dead links / switches after the transition.
    pub dead_links: usize,
    pub dead_switches: usize,
    /// Deroute-overlay entries installed (min + service tiers).
    pub deroutes: usize,
    /// `(src, dst)` switch pairs left unroutable by the failures.
    pub unreachable: u64,
}

/// Live fault-injection state (`Network::install_faults`).
struct FaultState {
    /// Flat transition schedule: `(cycle, target, fail?)`, indexed by the
    /// `idx` carried in [`Event::Fault`].
    schedule: Vec<(u64, FaultTarget, bool)>,
    rebuild: RebuildStrategy,
    /// Currently-failed links and switches.
    dead: DeadSet,
    /// Healthy tables the degraded views are computed against.
    base_tables: Arc<RoutingTables>,
    /// Router as constructed for the healthy topology; reconfiguration
    /// re-instantiates it over the degraded tables (`Router::with_tables`).
    base_router: Arc<dyn Router>,
    /// Degraded view of the previous transition (incremental patching).
    prev_view: Option<Arc<DegradedView>>,
    rebuild_log: Vec<RebuildRecord>,
}

/// Per-server injection state.
struct ServerState {
    /// Generated-but-not-injected packets: `(dst_server, gen_cycle, msg)`.
    queue: std::collections::VecDeque<(u32, u64, u32)>,
    /// NIC serialization: next cycle this server may inject a packet.
    free_at: u64,
}

/// The simulated network: topology + sharded switches + servers + router.
pub struct Network {
    pub topo: Arc<PhysTopology>,
    /// Currently-installed router. Healthy runs keep the construction-time
    /// router; fault reconfiguration swaps in a degraded-table clone (the
    /// worker threads observe the swap through `router_slot`).
    pub router: Arc<dyn Router>,
    /// Shared slot the compute phase reads its router from — the swap
    /// point for online reconfiguration (see `shard::RouterSlot`).
    router_slot: RouterSlot,
    pub cfg: SimConfig,
    /// Contiguous switch blocks, each owning its queues/arena/RNGs and the
    /// timing wheel of the events destined to its switches.
    shards: Vec<ShardState>,
    /// Shard index of every switch (blocks are near-equal, not exact
    /// divisions, so this lookup is the source of truth). Shared with the
    /// compute workers, which route cross-shard effects by it.
    switch_shard: Arc<Vec<u32>>,
    servers: Vec<ServerState>,
    /// Reused scratch buffer for the events popped by the serial Phase-1
    /// path (global wheel / fault / single-threaded runs).
    event_buf: Vec<Event>,
    /// Reused scratch for the serial path's canonically-sorted deliveries.
    deliver_buf: Vec<Packet>,
    /// Per-phase wall-time accumulator (`RunOpts::phase_timings`).
    timings: Option<PhaseTimings>,
    /// Dirty worklist of servers with queued source packets.
    active_servers: Vec<u32>,
    server_active: Vec<bool>,
    now: u64,
    /// Packets injected and not yet delivered (buffered in any shard or in
    /// flight on the wheel).
    live: usize,
    stats: SimStats,
    warmup: u64,
    window_end: u64,
    last_progress: u64,
    /// Packets sitting in server source queues (fast drain check).
    pending_sources: usize,
    /// Cycles actually simulated by `step` (the adaptive time advance
    /// jumps `now` without ticking, so `now - ticked` cycles were skipped).
    ticked: u64,
    /// Effective watchdog horizon: `cfg.watchdog_cycles`, floored so that
    /// packets legitimately in flight on a long wire (where no flit moves
    /// anywhere for up to `link_latency + serialization` cycles) are never
    /// declared a deadlock.
    watchdog: u64,
    max_hops: usize,
    max_degree: usize,
    /// Fault-injection state (`None` on healthy runs — the entire fault
    /// machinery then costs one `Option` check per cycle phase).
    faults: Option<FaultState>,
    /// Fault-schedule indices due this cycle, in wheel pop order (reused
    /// scratch).
    fault_pending: Vec<u32>,
}

impl Network {
    pub fn new(topo: Arc<PhysTopology>, router: Arc<dyn Router>, cfg: SimConfig) -> Self {
        assert!(cfg.link_latency >= 1, "link_latency must be >= 1 cycle");
        assert!(cfg.pkt_flits >= 1, "packets carry at least one flit");
        let n = topo.n;
        let vcs = router.num_vcs();
        let spc = cfg.servers_per_switch;
        let max_degree = topo.max_degree();
        let max_hops = router.max_hops();

        // Partition the switches into near-equal contiguous blocks. Every
        // block is non-empty because the shard count is clamped to n.
        let nshards = cfg.shards.clamp(1, n.max(1));
        let bounds: Vec<usize> = (0..=nshards).map(|k| k * n / nshards).collect();
        let mut switch_shard = vec![0u32; n];
        let mut shards = Vec::with_capacity(nshards);
        for k in 0..nshards {
            let (lo, hi) = (bounds[k], bounds[k + 1]);
            let mut queues = QueuePool::new();
            let mut switches = Vec::with_capacity(hi - lo);
            for s in lo..hi {
                switch_shard[s] = k as u32;
                let deg = topo.degree(s);
                let ports = deg + spc;
                let in_q0 = queues.num_queues();
                for _ in 0..ports * vcs {
                    queues.add_queue(cfg.input_cap_pkts);
                }
                let out_q0 = queues.num_queues();
                for _ in 0..ports * vcs {
                    queues.add_queue(cfg.output_cap_pkts);
                }
                let mut upstream = Vec::with_capacity(ports);
                for p in 0..deg {
                    let up_sw = topo.neighbor(s, p) as u32;
                    let up_port = topo.reverse_port(s, p) as u32;
                    upstream.push(Some((up_sw, up_port)));
                }
                upstream.resize(ports, None);
                let mut credits = vec![cfg.input_cap_pkts as u32; deg * vcs];
                // Ejection ports: a virtually infinite pool (never
                // decremented).
                credits.resize(ports * vcs, u32::MAX / 2);
                switches.push(Switch {
                    degree: deg,
                    ports,
                    vcs,
                    in_q0,
                    out_q0,
                    busy_until: vec![0; ports],
                    upstream,
                    link_free_at: vec![0; ports],
                    occ_flits: vec![0; ports],
                    grants_this_cycle: vec![0; ports],
                    last_grant_cycle: vec![u64::MAX; ports],
                    credits,
                    link_up: vec![true; ports],
                    work: 0,
                });
            }
            // One RNG stream per switch, derived from (seed, switch id):
            // allocator/VC randomness is independent of visit order and of
            // the shard count (the determinism invariant).
            let rngs = (lo..hi)
                .map(|s| Rng::derive(cfg.seed, SWITCH_RNG_STREAM + s as u64))
                .collect();
            shards.push(ShardState {
                lo,
                switches,
                queues,
                arena: PacketArena::with_capacity(1024),
                rngs,
                active: Vec::with_capacity(hi - lo),
                active_flag: vec![false; hi - lo],
                wheel: TimingWheel::new(),
                outboxes: (0..nshards).map(|_| Vec::new()).collect(),
                credit_out: (0..nshards).map(|_| Vec::new()).collect(),
                inbox: (0..nshards).map(|_| Vec::new()).collect(),
                credit_in: (0..nshards).map(|_| Vec::new()).collect(),
                pop_buf: Vec::new(),
                delivered: Vec::new(),
                link_flits: vec![0; (hi - lo) * max_degree],
                route_buf: crate::routing::CandidateBuf::new(),
                lane_buf: vec![0u32; max_degree + spc],
                progress: false,
            });
        }
        let servers = (0..n * spc)
            .map(|_| ServerState {
                queue: std::collections::VecDeque::new(),
                free_at: 0,
            })
            .collect();
        let stats = SimStats::new(n * spc, n * max_degree);
        let watchdog = cfg
            .watchdog_cycles
            .max(4 * (cfg.link_latency + cfg.pkt_flits as u64));
        Self {
            topo,
            router_slot: Arc::new(RwLock::new(router.clone())),
            router,
            cfg,
            shards,
            switch_shard: Arc::new(switch_shard),
            servers,
            event_buf: Vec::new(),
            deliver_buf: Vec::new(),
            timings: None,
            active_servers: Vec::with_capacity(n * spc),
            server_active: vec![false; n * spc],
            now: 0,
            live: 0,
            stats,
            warmup: 0,
            window_end: u64::MAX,
            last_progress: 0,
            pending_sources: 0,
            ticked: 0,
            watchdog,
            max_hops,
            max_degree,
            faults: None,
            fault_pending: Vec::new(),
        }
    }

    /// Install a fault schedule: `(cycle, target, fail?)` transitions,
    /// pre-validated by the engine (targets exist on the topology, the
    /// router supports online reconfiguration via `Router::tables` /
    /// `Router::with_tables`). Transitions become timing-wheel events, so
    /// the adaptive time advance and the shard determinism contract treat
    /// them exactly like packet events. Must be called before the run
    /// starts.
    pub fn install_faults(
        &mut self,
        schedule: Vec<(u64, FaultTarget, bool)>,
        rebuild: RebuildStrategy,
    ) {
        assert_eq!(self.now, 0, "faults must be installed before the run starts");
        let base_tables = self
            .router
            .tables()
            .expect("router supports online reconfiguration (engine-validated)")
            .clone();
        for (idx, &(cycle, target, _)) in schedule.iter().enumerate() {
            assert!(cycle >= 1, "fault cycles start at 1");
            // Fault events ride the owning shard's wheel — the shard of
            // the transition's target (links home to their lower-numbered
            // endpoint) — so the per-shard `next_event_at` min keeps
            // seeing pending reconfigurations. Fault runs pop serially
            // across all wheels, so ownership only has to be
            // deterministic, not load-balanced.
            let k = if self.cfg.global_wheel {
                0
            } else {
                match target {
                    FaultTarget::Link(a, b) => self.switch_shard[a.min(b) as usize] as usize,
                    FaultTarget::Switch(s) => self.switch_shard[s as usize] as usize,
                }
            };
            self.shards[k]
                .wheel
                .schedule(0, cycle, Event::Fault { idx: idx as u32 });
        }
        // Deroutes around failures legitimately exceed the healthy
        // topology's hop bounds; the livelock debug-asserts stay armed on
        // healthy runs only.
        self.max_hops = usize::MAX;
        self.faults = Some(FaultState {
            schedule,
            rebuild,
            dead: DeadSet::default(),
            base_tables,
            base_router: self.router.clone(),
            prev_view: None,
            rebuild_log: Vec::new(),
        });
    }

    /// Reconfiguration records from fault injection (empty on healthy
    /// runs): one entry per applied transition batch, with the wall-clock
    /// table rebuild latency.
    pub fn rebuild_log(&self) -> &[RebuildRecord] {
        self.faults.as_ref().map_or(&[], |f| &f.rebuild_log)
    }

    /// Currently-failed links and switches (empty/absent on healthy runs).
    pub fn dead_set(&self) -> Option<&DeadSet> {
        self.faults.as_ref().map(|f| &f.dead)
    }

    /// Current simulation cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Packets currently inside the network (injected, not delivered).
    pub fn live_packets(&self) -> usize {
        self.live
    }

    /// Number of compute shards this network was partitioned into
    /// (`cfg.shards` clamped to the switch count).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Cycles actually simulated (stepped) so far. With the adaptive time
    /// advance on, `cycles_ticked() <= now()`: the difference is the dead
    /// cycles the fast path jumped over. The benches report
    /// `ticked / covered` as the skip effectiveness ratio.
    pub fn cycles_ticked(&self) -> u64 {
        self.ticked
    }

    /// Cycles the clock jumped over without simulating.
    pub fn cycles_skipped(&self) -> u64 {
        self.now - self.ticked
    }

    /// Switches currently on the active worklists (those holding buffered
    /// packets, plus any awaiting lazy removal). Diagnostic accessor;
    /// `rust/tests/engine.rs` uses it to pin the idle-network invariant.
    pub fn active_switches(&self) -> usize {
        self.shards.iter().map(|sh| sh.active.len()).sum()
    }

    #[inline]
    fn in_window(&self, cycle: u64) -> bool {
        cycle >= self.warmup && cycle < self.window_end
    }

    /// Build the read-only context the compute phase needs (cloned into
    /// worker threads for multi-shard runs).
    fn compute_ctx(&self) -> ComputeCtx {
        ComputeCtx {
            topo: self.topo.clone(),
            router: self.router_slot.clone(),
            cfg: self.cfg.clone(),
            warmup: self.warmup,
            window_end: self.window_end,
            max_degree: self.max_degree,
            max_hops: self.max_hops,
            switch_shard: self.switch_shard.clone(),
            global_wheel: self.cfg.global_wheel,
        }
    }

    /// Run the simulation. Returns collected statistics or a deadlock /
    /// cycle-limit error.
    pub fn run(&mut self, workload: &mut dyn Workload, opts: &RunOpts) -> Result<SimStats, SimError> {
        self.warmup = opts.warmup;
        self.window_end = opts.warmup.saturating_add(opts.window.unwrap_or(u64::MAX / 2));
        self.last_progress = self.now;
        // Wall-clock phase breakdown lives on `Network`, not `SimStats`:
        // the bit-identity pins `assert_eq!` whole `SimStats` values, and
        // wall time is the one thing that may differ between runs.
        self.timings = opts.phase_timings.then(PhaseTimings::default);
        let ctx = self.compute_ctx();
        // Worker threads exist only for multi-shard runs, live for exactly
        // this run, and are joined on every exit path (WorkerPool::drop).
        let pool = if self.shards.len() > 1 {
            Some(WorkerPool::spawn(self.shards.len(), &ctx))
        } else {
            None
        };
        let mut monitor = opts
            .stop_rel_ci
            .map(|target| crate::metrics::StopMonitor::new(target, opts.warmup));
        let mut result: Result<(), SimError> = Ok(());
        loop {
            if opts.stop_when_drained
                && workload.exhausted()
                && self.live == 0
                && self.pending_sources == 0
            {
                break;
            }
            if self.now >= opts.max_cycles {
                if opts.stop_when_drained {
                    result = Err(SimError::CycleLimit(opts.max_cycles));
                }
                break;
            }
            if let Err(e) = self.step(workload, &ctx, pool.as_ref()) {
                result = Err(e);
                break;
            }
            if let Some(mon) = monitor.as_mut() {
                if mon.poll(self.now, &self.stats) {
                    break; // estimator converged: stop this point early
                }
            }
            if opts.time_skip {
                self.advance_to_next_event(&*workload, opts);
            }
        }
        drop(pool);
        // Fold the shard-local, window-gated link counters into the global
        // per-arc stats and reset them — on error paths too, so a failed
        // run's counters land in `self.stats` exactly as the pre-shard
        // engine's did (it recorded them there directly) instead of
        // leaking into a later run.
        for sh in &mut self.shards {
            for (i, v) in sh.link_flits.iter_mut().enumerate() {
                if *v != 0 {
                    let ls = i / self.max_degree;
                    let o = i % self.max_degree;
                    self.stats.link_flits[(sh.lo + ls) * self.max_degree + o] += *v;
                    *v = 0;
                }
            }
        }
        result?;
        let mut stats = std::mem::replace(
            &mut self.stats,
            SimStats::new(self.servers.len(), self.topo.n * self.max_degree),
        );
        // Lift the workload's flow-completion stats (if it keeps any) into
        // the run's SimStats: deliveries happen in canonical commit order,
        // so these are covered by the shard/skip determinism contract.
        stats.fct = workload.take_fct();
        stats.finish_cycle = self.now;
        stats.window_cycles = self.now.min(self.window_end).saturating_sub(self.warmup);
        if let Some(mon) = &monitor {
            stats.achieved_rel_ci = mon.achieved_rel_ci();
        }
        if let Some(tm) = self.timings.take() {
            let ms = |d: std::time::Duration| d.as_secs_f64() * 1e3;
            eprintln!(
                "phase-timings shards={} ticked={} wheel={:.1}ms compute={:.1}ms exchange={:.1}ms commit={:.1}ms",
                self.shards.len(),
                self.ticked,
                ms(tm.wheel),
                ms(tm.compute),
                ms(tm.exchange),
                ms(tm.commit),
            );
        }
        Ok(stats)
    }

    /// The adaptive time-advance fast path: called between cycles, jumps
    /// the clock to the earliest cycle at which anything can happen.
    ///
    /// The jump is **exact**, not approximate (DESIGN.md, "Time-advance
    /// and stopping invariants"): it only engages when every shard's
    /// active worklist is empty — a switch buffering even one packet draws
    /// allocator randomness each cycle, so such cycles must tick — and the
    /// target is the minimum of the three remaining event sources:
    ///
    /// * the per-shard timing wheels (`min` over
    ///   [`TimingWheel::next_event_at`] — fault events ride the owning
    ///   shard's wheel, so degraded runs are covered too);
    /// * the workload ([`Workload::next_injection_at`] — conservative by
    ///   default, e.g. Bernoulli pins it to `now` inside its horizon
    ///   because it consumes RNG every polled cycle);
    /// * server NICs mid-serialization (`free_at` of servers with queued
    ///   packets; an eligible server with a free NIC implies its switch
    ///   FIFO was full, i.e. an active switch, so it never slips through).
    ///
    /// Skipped cycles therefore move no flit, deliver no packet and draw
    /// no randomness in the fixed-tick engine either — `SimStats` are
    /// bit-identical with the fast path on or off, for every shard count.
    fn advance_to_next_event(&mut self, workload: &dyn Workload, opts: &RunOpts) {
        if self.shards.iter().any(|sh| !sh.is_idle()) {
            return;
        }
        // The run loop is about to break anyway; jumping to `max_cycles`
        // here would misreport `finish_cycle`.
        if opts.stop_when_drained
            && workload.exhausted()
            && self.live == 0
            && self.pending_sources == 0
        {
            return;
        }
        // Cheap O(1) workload check before the O(slots) wheel scan: an
        // open-loop workload inside its horizon pins the next injection to
        // `now` (it draws RNG every polled cycle), making any jump
        // impossible — bail before paying for the wheel traversal.
        let injection = workload.next_injection_at(self.now);
        if injection == Some(self.now) {
            return;
        }
        let mut next: Option<u64> = None;
        for sh in &self.shards {
            if let Some(t) = sh.wheel.next_event_at() {
                next = Some(next.map_or(t, |n| n.min(t)));
            }
        }
        if let Some(t) = injection {
            next = Some(next.map_or(t, |n| n.min(t)));
        }
        for &srv in &self.active_servers {
            let s = &self.servers[srv as usize];
            if !s.queue.is_empty() {
                let t = s.free_at.max(self.now);
                next = Some(next.map_or(t, |n| n.min(t)));
            }
        }
        // Nothing will ever happen again: fast-forward to the cycle limit
        // (exactly where the fixed-tick loop would grind to).
        let target = next.unwrap_or(opts.max_cycles).min(opts.max_cycles);
        if target > self.now {
            self.now = target;
        }
    }

    /// One simulated cycle: per-shard event pop+dispatch (parallel when a
    /// worker pool exists), serial injection, the (possibly parallel)
    /// per-shard compute phase, the serial cross-shard exchange, then the
    /// (possibly parallel) per-shard commit.
    fn step(
        &mut self,
        workload: &mut dyn Workload,
        ctx: &ComputeCtx,
        pool: Option<&WorkerPool>,
    ) -> Result<(), SimError> {
        let now = self.now;
        let flits = self.cfg.pkt_flits as u64;

        // ---- Phase 1: timing-wheel events (faults, arrivals, deliveries).
        // Every event already sits on the wheel of the shard that owns its
        // effect (arrivals: destination switch; deliveries: ejecting
        // switch), so the common path pops and dispatches per shard in
        // parallel. The serial fallback covers single-shard runs,
        // `--global-wheel` mode (everything homes to shard 0), and fault
        // runs — fault transitions interleave with packet events and
        // mutate cross-shard state, so they take the one-thread path. ----
        let t0 = self.timings.is_some().then(std::time::Instant::now);
        match pool {
            Some(p) if !self.cfg.global_wheel && self.faults.is_none() => {
                p.run_phase(Phase::Pop, &mut self.shards, now);
                // Deliveries are staged per shard (sorted by destination
                // server) and applied here on the main thread: shards own
                // ascending contiguous server ranges, so draining shards
                // in ascending order visits deliveries in global
                // `dst_server` order — the same sequence the serial path
                // produces after its sort.
                for k in 0..self.shards.len() {
                    let mut delivered = std::mem::take(&mut self.shards[k].delivered);
                    for pkt in delivered.drain(..) {
                        self.process_delivered(pkt, now, workload);
                    }
                    self.shards[k].delivered = delivered;
                }
            }
            _ => self.pop_events_serial(now, workload),
        }
        if let (Some(tm), Some(t)) = (self.timings.as_mut(), t0) {
            tm.wheel += t.elapsed();
        }

        // ---- Phase 2: workload generation into source queues. ----
        {
            let servers = &mut self.servers;
            let pending = &mut self.pending_sources;
            let active = &mut self.active_servers;
            let active_flag = &mut self.server_active;
            workload.poll(now, &mut |src: u32, dst: u32, msg: u32| {
                servers[src as usize].queue.push_back((dst, now, msg));
                *pending += 1;
                if !active_flag[src as usize] {
                    active_flag[src as usize] = true;
                    active.push(src);
                }
            });
        }

        // ---- Phase 3: injection (server NIC → switch input FIFO), active
        // servers only. ----
        let spc = self.cfg.servers_per_switch;
        let mut idx = 0;
        while idx < self.active_servers.len() {
            let srv = self.active_servers[idx] as usize;
            if self.servers[srv].queue.is_empty() {
                self.server_active[srv] = false;
                self.active_servers.swap_remove(idx);
                continue;
            }
            if self.servers[srv].free_at > now {
                idx += 1;
                continue;
            }
            let sw = srv / spc;
            if self
                .faults
                .as_ref()
                .map_or(false, |f| !f.dead.switch_alive(sw))
            {
                // Source switch is down: traffic holds at the NIC until
                // (unless) the switch recovers.
                idx += 1;
                continue;
            }
            let k = self.switch_shard[sw] as usize;
            let sh = &mut self.shards[k];
            let ls = sw - sh.lo;
            let local = srv % spc;
            let port = sh.switches[ls].degree + local;
            // Injection always lands on VC 0 (cf. §2.1.2: MIN packets must
            // enter on the lowest-ordered VC).
            let q = sh.switches[ls].in_q(port, 0);
            if sh.queues.len(q) >= self.cfg.input_cap_pkts {
                idx += 1;
                continue; // backpressure into the source queue
            }
            let (dst, gen_cycle, msg) = self.servers[srv].queue.pop_front().unwrap();
            self.servers[srv].free_at = now + flits;
            self.pending_sources -= 1;
            let dst_sw = (dst as usize / spc) as u32;
            let id = sh.arena.alloc(Packet {
                src_server: srv as u32,
                dst_server: dst,
                src_sw: sw as u32,
                dst_sw,
                intermediate: NO_SWITCH,
                hops: 0,
                vc: 0,
                scratch: 0,
                blocked: 0,
                gen_cycle,
                inject_cycle: now,
                flits: self.cfg.pkt_flits,
                msg,
            });
            sh.queues.push_back(q, id);
            sh.switches[ls].work += 1;
            sh.activate(sw as u32);
            self.live += 1;
            if self.in_window(now) {
                self.stats.injected_per_server[srv] += 1;
            }
            idx += 1;
        }

        // ---- Phases 4+5 (compute): crossbar allocation then link
        // transmission, per active switch of each shard. Shards touch only
        // their own state; cross-switch effects land in per-destination
        // outbox rows. ----
        let t0 = self.timings.is_some().then(std::time::Instant::now);
        match pool {
            Some(p) => p.run_phase(Phase::Compute, &mut self.shards, now),
            None => {
                for sh in &mut self.shards {
                    sh.compute(now, ctx);
                }
            }
        }
        if let (Some(tm), Some(t)) = (self.timings.as_mut(), t0) {
            tm.compute += t.elapsed();
        }

        // ---- Phase 6a (exchange): serial O(shards²) pointer swap. Shard
        // j's outbox row for shard k becomes shard k's inbox row from
        // shard j (likewise for credit returns). Inbox rows are empty
        // here — the previous commit drained them — so the swap also
        // ping-pongs the row capacities back as fresh outboxes. ----
        let t0 = self.timings.is_some().then(std::time::Instant::now);
        let n = self.shards.len();
        for j in 0..n {
            for k in 0..n {
                if j == k {
                    let sh = &mut self.shards[j];
                    std::mem::swap(&mut sh.outboxes[k], &mut sh.inbox[j]);
                    std::mem::swap(&mut sh.credit_out[k], &mut sh.credit_in[j]);
                } else {
                    let (a, b) = pair_mut(&mut self.shards, j, k);
                    std::mem::swap(&mut a.outboxes[k], &mut b.inbox[j]);
                    std::mem::swap(&mut a.credit_out[k], &mut b.credit_in[j]);
                }
            }
        }
        if let (Some(tm), Some(t)) = (self.timings.as_mut(), t0) {
            tm.exchange += t.elapsed();
        }

        // ---- Phase 6b (commit): each shard drains its inbox rows in
        // ascending source-shard order onto its own wheel. Shards hold
        // ascending switch ranges and emit in ascending (switch, port)
        // order, so every destination wheel sees its events in the same
        // sequence at any shard count; credit returns are commutative
        // `+= 1`s, so their per-shard grouping is free. ----
        let t0 = self.timings.is_some().then(std::time::Instant::now);
        match pool {
            Some(p) => p.run_phase(Phase::Commit, &mut self.shards, now),
            None => {
                for sh in &mut self.shards {
                    sh.commit_phase(now);
                }
            }
        }
        if self.shards.iter().any(|sh| sh.progress) {
            self.last_progress = now;
        }
        if let (Some(tm), Some(t)) = (self.timings.as_mut(), t0) {
            tm.commit += t.elapsed();
        }

        // ---- Watchdog: live packets but no flit movement for the whole
        // horizon ⇒ structured no-forward-progress report. ----
        if self.live > 0 && now - self.last_progress > self.watchdog {
            return Err(SimError::Deadlock {
                cycle: now,
                live: self.live,
                idle: now - self.last_progress,
                stalled: self.collect_stalled(STALLED_REPORT_CAP),
            });
        }

        self.ticked += 1;
        self.now += 1;
        Ok(())
    }

    /// Serial Phase-1 path: pop every shard's wheel in ascending shard
    /// order and dispatch on the main thread. Used for single-shard runs,
    /// `--global-wheel` mode, and fault runs. Dispatch effects are
    /// canonically ordered so this path and the parallel one produce
    /// bit-identical state: dead-switch casualties requeue in
    /// `(switch, port)` order and deliveries apply in `dst_server` order,
    /// both independent of which wheel each event popped from.
    fn pop_events_serial(&mut self, now: u64, workload: &mut dyn Workload) {
        let mut events = std::mem::take(&mut self.event_buf);
        events.clear();
        for sh in &mut self.shards {
            sh.wheel.pop_due(now, &mut events);
        }
        // Fault transitions apply before packet events: an arrival due
        // this same cycle had already crossed its link when the link died,
        // so it lands normally — unless its destination *switch* died, in
        // which case it is dropped and retransmitted like the in-flight
        // packets the fault pass extracts from the wheels.
        if self.faults.is_some() {
            for ev in events.iter() {
                if let Event::Fault { idx } = ev {
                    self.fault_pending.push(*idx);
                }
            }
            if !self.fault_pending.is_empty() {
                self.apply_due_faults(now);
            }
        }
        let mut dead_arrivals: Vec<(u32, u32, u8, Packet)> = Vec::new();
        let mut delivered = std::mem::take(&mut self.deliver_buf);
        for ev in events.drain(..) {
            match ev {
                Event::Fault { .. } => {} // applied above, before packet events
                Event::Arrive { sw, port, vc, pkt } => {
                    if self
                        .faults
                        .as_ref()
                        .map_or(false, |f| !f.dead.switch_alive(sw as usize))
                    {
                        dead_arrivals.push((sw, port, vc, pkt));
                        continue;
                    }
                    let k = self.switch_shard[sw as usize] as usize;
                    self.shards[k].dispatch_arrive(sw, port, vc, pkt);
                }
                Event::Deliver { pkt } => delivered.push(pkt),
            }
        }
        self.event_buf = events;
        // A link carries at most one arrival per cycle, so (switch, port)
        // is unique within a cycle and this sort gives one canonical
        // requeue order at any shard count.
        dead_arrivals.sort_unstable_by_key(|&(sw, port, _, _)| (sw, port));
        for (sw, port, vc, pkt) in dead_arrivals {
            let u = self.topo.neighbor(sw as usize, port as usize) as u32;
            let up = self.topo.reverse_port(sw as usize, port as usize) as u32;
            self.restore_credit(u, up, vc);
            self.requeue_dropped(pkt);
        }
        delivered.sort_unstable_by_key(|p| p.dst_server);
        for pkt in delivered.drain(..) {
            self.process_delivered(pkt, now, workload);
        }
        self.deliver_buf = delivered;
    }

    /// Deliver one packet to its destination server: livelock check,
    /// window-gated stats, workload notification. Both Phase-1 paths
    /// invoke this in global `dst_server` order.
    fn process_delivered(&mut self, pkt: Packet, now: u64, workload: &mut dyn Workload) {
        debug_assert!(
            (pkt.hops as usize) <= self.max_hops,
            "livelock bound violated: {} hops > {} ({})",
            pkt.hops,
            self.max_hops,
            self.router.name()
        );
        if self.in_window(now) {
            self.stats.delivered_flits += pkt.flits as u64;
            self.stats.delivered_packets += 1;
        }
        if self.in_window(pkt.gen_cycle) {
            self.stats.latency.record(now - pkt.gen_cycle);
            let h = (pkt.hops as usize).min(self.stats.hops.len() - 1);
            self.stats.hops[h] += 1;
        }
        self.live -= 1;
        workload.on_delivered(pkt.src_server, pkt.dst_server, pkt.msg, now);
    }

    /// Apply the fault transitions collected in `fault_pending` (phase 1).
    ///
    /// Order of operations — all deterministic and shard-count-invariant:
    ///
    /// 1. fold every due transition into the dead set;
    /// 2. refresh each switch's per-port `link_up` mask (consumed by
    ///    routing candidate construction, `SwitchView::has_space` and both
    ///    transmit paths);
    /// 3. drop in-flight packets whose traversed link is now dead —
    ///    extracted from every shard's wheel, then sorted into canonical
    ///    `(cycle, switch, port)` order so the requeue sequence is
    ///    shard-count-invariant — and restore the downstream input-FIFO
    ///    credit each one held;
    /// 4. drain output queues committed onto dead edges and every queue of
    ///    a dead switch, in ascending `(switch, port, vc)` order,
    ///    requeueing the packets at their source NICs;
    /// 5. rebuild the routing tables over the degraded topology
    ///    (stop-the-world recompile or incremental patch) and swap the
    ///    router every shard routes with from this cycle on.
    fn apply_due_faults(&mut self, now: u64) {
        let mut st = self.faults.take().expect("fault state present");
        // Due indices were collected across per-shard wheels in pop order;
        // sorting restores schedule order, so same-cycle transitions fold
        // in the order the scenario listed them at any shard count.
        self.fault_pending.sort_unstable();
        for &idx in &self.fault_pending {
            let (_, target, fail) = st.schedule[idx as usize];
            match (target, fail) {
                (FaultTarget::Link(a, b), true) => st.dead.fail_link(a, b),
                (FaultTarget::Link(a, b), false) => st.dead.recover_link(a, b),
                (FaultTarget::Switch(s), true) => st.dead.fail_switch(s),
                (FaultTarget::Switch(s), false) => st.dead.recover_switch(s),
            }
        }
        self.fault_pending.clear();

        // 2. Per-switch link masks.
        for sh in &mut self.shards {
            for (ls, sw) in sh.switches.iter_mut().enumerate() {
                let s = sh.lo + ls;
                let alive = st.dead.switch_alive(s);
                for p in 0..sw.degree {
                    sw.link_up[p] = alive && st.dead.edge_alive(s, self.topo.neighbor(s, p));
                }
            }
        }

        // 3. In-flight drops. Each wheel's scan order is fixed but the
        // concatenation across shards is not, so sort the casualties into
        // (cycle, switch, port) order — unique per in-flight packet, since
        // a link carries at most one arrival per cycle.
        let mut dropped: Vec<(u64, Event)> = Vec::new();
        {
            let topo = &self.topo;
            let dead = &st.dead;
            for sh in &mut self.shards {
                sh.wheel.extract_if(
                    |ev| match ev {
                        Event::Arrive { sw, port, .. } => {
                            let v = *sw as usize;
                            !dead.edge_alive(topo.neighbor(v, *port as usize), v)
                        }
                        _ => false,
                    },
                    &mut dropped,
                );
            }
        }
        dropped.sort_unstable_by_key(|(when, ev)| match ev {
            Event::Arrive { sw, port, .. } => (*when, *sw, *port),
            _ => unreachable!("only arrivals are extracted"),
        });
        for (_, ev) in dropped {
            let Event::Arrive { sw, port, vc, pkt } = ev else {
                unreachable!("only arrivals are extracted")
            };
            let u = self.topo.neighbor(sw as usize, port as usize) as u32;
            let up = self.topo.reverse_port(sw as usize, port as usize) as u32;
            self.restore_credit(u, up, vc);
            self.requeue_dropped(pkt);
        }

        // 4. Queue drains.
        for s in 0..self.topo.n {
            let sw_dead = !st.dead.switch_alive(s);
            let k = self.switch_shard[s] as usize;
            let ls = s - self.shards[k].lo;
            let (degree, ports, vcs) = {
                let sw = &self.shards[k].switches[ls];
                (sw.degree, sw.ports, sw.vcs)
            };
            for p in 0..ports {
                let out_dead = if p < degree {
                    sw_dead || !st.dead.edge_alive(s, self.topo.neighbor(s, p))
                } else {
                    sw_dead
                };
                for vc in 0..vcs {
                    if out_dead {
                        // Output-queue packets never consumed the link
                        // credit (that happens at transmit): no credit
                        // moves, just uncount and retransmit.
                        loop {
                            let pkt = {
                                let sh = &mut self.shards[k];
                                let q = sh.switches[ls].out_q(p, vc);
                                let Some(id) = sh.queues.pop_front(q) else { break };
                                let pkt = sh.arena.get(id).clone();
                                sh.arena.free(id);
                                let swm = &mut sh.switches[ls];
                                swm.occ_flits[p] =
                                    swm.occ_flits[p].saturating_sub(pkt.flits as u32);
                                swm.work -= 1;
                                pkt
                            };
                            self.requeue_dropped(pkt);
                        }
                    }
                    if sw_dead {
                        // Input-FIFO packets of a dead switch each hold
                        // one upstream credit (returned on grant in
                        // healthy operation) — restore it.
                        loop {
                            let (pkt, upstream) = {
                                let sh = &mut self.shards[k];
                                let q = sh.switches[ls].in_q(p, vc);
                                let Some(id) = sh.queues.pop_front(q) else { break };
                                let pkt = sh.arena.get(id).clone();
                                sh.arena.free(id);
                                sh.switches[ls].work -= 1;
                                (pkt, sh.switches[ls].upstream[p])
                            };
                            if let Some((usw, uport)) = upstream {
                                self.restore_credit(usw, uport, vc as u8);
                            }
                            self.requeue_dropped(pkt);
                        }
                    }
                }
            }
        }

        // 5. Rebuild and swap. Wall-clock latency goes to the rebuild log,
        // never into SimStats (which must stay bit-deterministic).
        let t0 = std::time::Instant::now();
        let view = if st.dead.is_empty() {
            None
        } else {
            let v = match (st.rebuild, &st.prev_view) {
                (RebuildStrategy::Patch, Some(prev)) => {
                    st.base_tables.degraded_patch(prev, &st.dead)
                }
                _ => st.base_tables.degraded_full(&st.dead),
            };
            Some(Arc::new(v))
        };
        let micros = t0.elapsed().as_micros() as u64;
        let (deroutes, unreachable) = view
            .as_ref()
            .map_or((0, 0), |v| (v.min.len() + v.svc.len(), v.unreachable_pairs));
        st.prev_view = view.clone();
        let tables = Arc::new(st.base_tables.with_degraded(view));
        let router = st
            .base_router
            .with_tables(tables)
            .expect("router supports online reconfiguration (engine-validated)");
        self.router = router.clone();
        *self.router_slot.write().expect("router slot poisoned") = router;
        st.rebuild_log.push(RebuildRecord {
            cycle: now,
            strategy: st.rebuild.name(),
            micros,
            dead_links: st.dead.dead_links().count(),
            dead_switches: st.dead.dead_switches().count(),
            deroutes,
            unreachable,
        });
        // Reconfiguration resets the forward-progress clock: rerouted
        // traffic gets a full watchdog horizon before a deadlock verdict.
        self.last_progress = now;
        self.faults = Some(st);
    }

    /// Return one credit to `(sw, port, vc)` — the downstream input-FIFO
    /// slot a dropped packet held.
    fn restore_credit(&mut self, sw: u32, port: u32, vc: u8) {
        let k = self.switch_shard[sw as usize] as usize;
        let sh = &mut self.shards[k];
        let ls = sw as usize - sh.lo;
        sh.switches[ls].return_credit(port as usize, vc as usize);
    }

    /// Drop a fault casualty and requeue it at its source NIC for
    /// retransmission. `gen_cycle` is preserved (latency and FCT
    /// accounting span the retransmission); routing state restarts fresh
    /// at re-injection.
    fn requeue_dropped(&mut self, pkt: Packet) {
        let srv = pkt.src_server as usize;
        self.servers[srv]
            .queue
            .push_back((pkt.dst_server, pkt.gen_cycle, pkt.msg));
        self.pending_sources += 1;
        if !self.server_active[srv] {
            self.server_active[srv] = true;
            self.active_servers.push(pkt.src_server);
        }
        self.live -= 1;
        self.stats.dropped_packets += 1;
        self.stats.retransmitted_packets += 1;
    }

    /// First `cap` ports still buffering packets, in canonical
    /// `(switch, port)` order — the structured payload of a watchdog trip.
    fn collect_stalled(&self, cap: usize) -> Vec<StalledPort> {
        let mut out = Vec::new();
        for sh in &self.shards {
            for (ls, sw) in sh.switches.iter().enumerate() {
                if sw.work == 0 {
                    continue;
                }
                for p in 0..sw.ports {
                    let queued_in = sw.input_occupancy(&sh.queues, p);
                    let queued_out = sw.output_queued(&sh.queues, p);
                    if queued_in + queued_out > 0 {
                        out.push(StalledPort {
                            switch: (sh.lo + ls) as u32,
                            port: p as u32,
                            queued_in,
                            queued_out,
                        });
                        if out.len() >= cap {
                            return out;
                        }
                    }
                }
            }
        }
        out
    }

    /// Total occupancy snapshot (flits buffered per output port of a
    /// switch) — used by the artifact-validation harness and tests.
    pub fn occupancy_snapshot(&self, s: usize) -> Vec<u32> {
        let sh = &self.shards[self.switch_shard[s] as usize];
        sh.switches[s - sh.lo].occ_flits.clone()
    }
}

/// Disjoint `&mut` references to two distinct slots of one slice —
/// the exchange phase swaps outbox/inbox rows between shard pairs.
#[inline]
fn pair_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{routing_by_name, topology_by_name};

    fn tiny_net(n: usize, shards: usize) -> Network {
        let topo = Arc::new(topology_by_name(&format!("fm{n}")).unwrap());
        let router = routing_by_name("min", topo.clone(), 54).unwrap();
        let cfg = SimConfig {
            servers_per_switch: 2,
            shards,
            ..SimConfig::default()
        };
        Network::new(topo, router, cfg)
    }

    #[test]
    fn partition_covers_every_switch_exactly_once() {
        for shards in [1usize, 2, 3, 7, 10] {
            let net = tiny_net(10, shards);
            assert_eq!(net.num_shards(), shards.min(10));
            // Every switch resolves to a shard that actually owns it.
            for s in 0..10 {
                let k = net.switch_shard[s] as usize;
                let sh = &net.shards[k];
                assert!(s >= sh.lo && s < sh.lo + sh.switches.len(), "switch {s}");
            }
            // Blocks are contiguous and ascending.
            let mut total = 0;
            let mut next_lo = 0;
            for sh in &net.shards {
                assert_eq!(sh.lo, next_lo);
                assert!(!sh.switches.is_empty());
                next_lo += sh.switches.len();
                total += sh.switches.len();
            }
            assert_eq!(total, 10);
        }
    }

    #[test]
    fn shard_count_clamps_to_switch_count() {
        let net = tiny_net(4, 64);
        assert_eq!(net.num_shards(), 4);
        assert_eq!(net.active_switches(), 0);
        assert_eq!(net.live_packets(), 0);
    }
}
