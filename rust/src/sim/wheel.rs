//! Overflow-safe hierarchical timing wheel.
//!
//! The original simulator used a single 64-slot wheel and could only
//! schedule events strictly less than 64 cycles ahead — guarded by a
//! `debug_assert` alone, so a release build with `link_latency + pkt_flits
//! >= 64` silently aliased future events onto earlier cycles. This wheel
//! makes overflow impossible:
//!
//! * **near** — 64 slots at 1-cycle resolution (the common case:
//!   `link_latency + serialization` is a handful of cycles);
//! * **far** — 64 slots at 64-cycle resolution; cascaded into `near` at
//!   every 64-cycle epoch boundary;
//! * **overflow** — an unsorted spill list for events ≥ 4096 cycles ahead,
//!   rescanned at epoch boundaries (amortized: 1/64th of a scan per cycle,
//!   and empty unless latencies are extreme).
//!
//! Events due at the same cycle pop in near-slot insertion order: direct
//! schedules (dt < 64) append as they happen; far/overflow events append
//! when their epoch cascades. The order is fully deterministic for a
//! deterministic schedule sequence — which is what keeps the simulator's
//! FIFO arrival semantics reproducible — but it is not global
//! schedule-time order across wheel levels.
//!
//! The phase-parallel simulator leans on exactly this property: shard
//! compute phases never touch the wheel. They stage transfers in per-shard
//! outboxes, and the serial commit phase schedules them in canonical
//! `(switch, port)` order — so the wheel sees one deterministic schedule
//! sequence regardless of the shard count, and same-cycle pops (hence FIFO
//! arrival order downstream) are bit-identical to the serial engine's.

/// Slots per level; also the cascade epoch length in cycles.
pub const NEAR: usize = 64;

/// A two-level hierarchical timing wheel with an overflow spill list.
pub struct TimingWheel<T> {
    near: Vec<Vec<(u64, T)>>,
    far: Vec<Vec<(u64, T)>>,
    overflow: Vec<(u64, T)>,
    len: usize,
}

impl<T> Default for TimingWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimingWheel<T> {
    pub fn new() -> Self {
        Self {
            near: (0..NEAR).map(|_| Vec::new()).collect(),
            far: (0..NEAR).map(|_| Vec::new()).collect(),
            overflow: Vec::new(),
            len: 0,
        }
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule `ev` for cycle `when` (must be in the future).
    pub fn schedule(&mut self, now: u64, when: u64, ev: T) {
        debug_assert!(when > now, "events must be scheduled in the future");
        self.len += 1;
        self.place(now, when, ev);
    }

    fn place(&mut self, now: u64, when: u64, ev: T) {
        let dt = when - now;
        if dt < NEAR as u64 {
            self.near[(when % NEAR as u64) as usize].push((when, ev));
        } else if dt < (NEAR * NEAR) as u64 {
            self.far[((when / NEAR as u64) % NEAR as u64) as usize].push((when, ev));
        } else {
            self.overflow.push((when, ev));
        }
    }

    /// Pop every event due at exactly `now` into `out`. Must be called once
    /// per cycle with monotonically non-decreasing `now`.
    pub fn pop_due(&mut self, now: u64, out: &mut Vec<T>) {
        if now % NEAR as u64 == 0 {
            self.cascade(now);
        }
        let slot = (now % NEAR as u64) as usize;
        for (when, ev) in self.near[slot].drain(..) {
            debug_assert_eq!(when, now, "near slot holds only due events");
            self.len -= 1;
            out.push(ev);
        }
    }

    /// Epoch boundary: re-dispatch the current far slot (all its events fall
    /// inside the next 64 cycles) and any overflow events that have come
    /// within range of the two wheel levels.
    fn cascade(&mut self, now: u64) {
        let slot = ((now / NEAR as u64) % NEAR as u64) as usize;
        let due = std::mem::take(&mut self.far[slot]);
        for (when, ev) in due {
            debug_assert!(when >= now && when - now < NEAR as u64);
            self.place(now, when, ev);
        }
        if !self.overflow.is_empty() {
            let spill = std::mem::take(&mut self.overflow);
            for (when, ev) in spill {
                if when - now < (NEAR * NEAR) as u64 {
                    self.place(now, when, ev);
                } else {
                    self.overflow.push((when, ev));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the wheel from cycle `start`, collecting (cycle, event) pops.
    fn drain(w: &mut TimingWheel<u32>, start: u64, cycles: u64) -> Vec<(u64, u32)> {
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for now in start..start + cycles {
            buf.clear();
            w.pop_due(now, &mut buf);
            for &ev in &buf {
                got.push((now, ev));
            }
        }
        got
    }

    #[test]
    fn near_events_fire_on_time() {
        let mut w = TimingWheel::new();
        w.schedule(0, 1, 1u32);
        w.schedule(0, 63, 63);
        w.schedule(0, 5, 5);
        assert_eq!(w.len(), 3);
        let got = drain(&mut w, 0, 64);
        assert_eq!(got, vec![(1, 1), (5, 5), (63, 63)]);
        assert!(w.is_empty());
    }

    #[test]
    fn far_events_cascade_exactly_once() {
        let mut w = TimingWheel::new();
        // dt = 64 (the first value the old single-level wheel corrupted),
        // plus assorted points across the far range.
        for &when in &[64u64, 65, 100, 127, 128, 4095] {
            w.schedule(0, when, when as u32);
        }
        let got = drain(&mut w, 0, 4096);
        let want: Vec<(u64, u32)> = [64u64, 65, 100, 127, 128, 4095]
            .iter()
            .map(|&x| (x, x as u32))
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn overflow_events_survive_multiple_epochs() {
        let mut w = TimingWheel::new();
        w.schedule(0, 4096, 1u32); // exactly the overflow boundary
        w.schedule(0, 10_000, 2);
        w.schedule(0, 123_456, 3);
        let got = drain(&mut w, 0, 130_000);
        assert_eq!(got, vec![(4096, 1), (10_000, 2), (123_456, 3)]);
        assert!(w.is_empty());
    }

    #[test]
    fn scheduling_from_nonzero_now_and_mid_epoch() {
        let mut w = TimingWheel::new();
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for now in 1000..1500u64 {
            buf.clear();
            w.pop_due(now, &mut buf);
            for &ev in &buf {
                got.push((now, ev));
            }
            if now == 1001 {
                // Mid-epoch schedules landing in this and later epochs.
                w.schedule(now, 1002, 10);
                w.schedule(now, 1064, 11);
                w.schedule(now, 1065, 12);
                w.schedule(now, 1201, 13);
            }
        }
        assert_eq!(got, vec![(1002, 10), (1064, 11), (1065, 12), (1201, 13)]);
    }

    #[test]
    fn same_cycle_pops_in_insertion_order() {
        let mut w = TimingWheel::new();
        w.schedule(0, 10, 1u32);
        w.schedule(0, 10, 2);
        w.schedule(3, 10, 3);
        let got = drain(&mut w, 0, 16);
        assert_eq!(got, vec![(10, 1), (10, 2), (10, 3)]);
    }
}
