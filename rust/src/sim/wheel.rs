//! Overflow-safe hierarchical timing wheel.
//!
//! The original simulator used a single 64-slot wheel and could only
//! schedule events strictly less than 64 cycles ahead — guarded by a
//! `debug_assert` alone, so a release build with `link_latency + pkt_flits
//! >= 64` silently aliased future events onto earlier cycles. This wheel
//! makes overflow impossible:
//!
//! * **near** — 64 slots at 1-cycle resolution (the common case:
//!   `link_latency + serialization` is a handful of cycles);
//! * **far** — 64 slots at 64-cycle resolution; cascaded into `near` at
//!   every 64-cycle epoch boundary;
//! * **overflow** — an unsorted spill list for events ≥ 4096 cycles ahead,
//!   rescanned at epoch boundaries (amortized: 1/64th of a scan per cycle,
//!   and empty unless latencies are extreme).
//!
//! Events due at the same cycle pop in near-slot insertion order: direct
//! schedules (dt < 64) append as they happen; far/overflow events append
//! when their epoch cascades. The order is fully deterministic for a
//! deterministic schedule sequence — which is what keeps the simulator's
//! FIFO arrival semantics reproducible — but it is not global
//! schedule-time order across wheel levels.
//!
//! The phase-parallel simulator leans on exactly this property: each
//! shard owns the wheel holding the events destined to its own switches.
//! Compute phases never touch any wheel — they stage transfers in
//! per-(source, destination)-shard outboxes, and the commit phase feeds
//! each wheel its incoming events in ascending source-shard order. Shards
//! hold ascending contiguous switch ranges, so that drain order equals
//! the global `(switch, port)` emission order — every wheel sees one
//! deterministic schedule sequence regardless of the shard count, and
//! same-cycle pops (hence FIFO arrival order downstream) are
//! bit-identical to the serial engine's. See DESIGN.md, "Phase-parallel
//! invariants".

/// Slots per level; also the cascade epoch length in cycles.
pub const NEAR: usize = 64;

/// A two-level hierarchical timing wheel with an overflow spill list.
pub struct TimingWheel<T> {
    near: Vec<Vec<(u64, T)>>,
    far: Vec<Vec<(u64, T)>>,
    overflow: Vec<(u64, T)>,
    len: usize,
    /// Highest epoch whose far slot has been cascaded into the near wheel.
    /// Tracked explicitly (rather than inferred from `now % NEAR == 0`) so
    /// `pop_due` may be driven with forward *jumps*: the adaptive
    /// time-advance fast path skips straight to the next event cycle, and
    /// every epoch boundary crossed by the jump is cascaded on arrival in
    /// the exact order cycle-by-cycle driving would have.
    epoch: u64,
}

impl<T> Default for TimingWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimingWheel<T> {
    pub fn new() -> Self {
        Self {
            near: (0..NEAR).map(|_| Vec::new()).collect(),
            far: (0..NEAR).map(|_| Vec::new()).collect(),
            overflow: Vec::new(),
            len: 0,
            epoch: 0,
        }
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule `ev` for cycle `when` (must be in the future).
    pub fn schedule(&mut self, now: u64, when: u64, ev: T) {
        debug_assert!(when > now, "events must be scheduled in the future");
        self.len += 1;
        self.place(now, when, ev);
    }

    fn place(&mut self, now: u64, when: u64, ev: T) {
        let dt = when - now;
        if dt < NEAR as u64 {
            self.near[(when % NEAR as u64) as usize].push((when, ev));
        } else if dt < (NEAR * NEAR) as u64 {
            self.far[((when / NEAR as u64) % NEAR as u64) as usize].push((when, ev));
        } else {
            self.overflow.push((when, ev));
        }
    }

    /// Earliest cycle with a scheduled event, or `None` when empty.
    ///
    /// A linear scan over every stored event. This is deliberately simple:
    /// the adaptive time-advance fast path only queries it when the whole
    /// network is quiescent, i.e. when few events are pending — and its
    /// cost is paid *instead of* ticking every skipped cycle, not on top.
    /// `rust/src/sim/wheel.rs` tests pin agreement with a naive shadow
    /// scheduler across random schedules spanning all three wheel levels.
    pub fn next_event_at(&self) -> Option<u64> {
        if self.len == 0 {
            return None;
        }
        let mut best: Option<u64> = None;
        let mut fold = |when: u64| {
            best = Some(match best {
                Some(b) => b.min(when),
                None => when,
            });
        };
        for slot in &self.near {
            for (when, _) in slot.iter() {
                fold(*when);
            }
        }
        for slot in &self.far {
            for (when, _) in slot.iter() {
                fold(*when);
            }
        }
        for (when, _) in self.overflow.iter() {
            fold(*when);
        }
        best
    }

    /// Pop every event due at exactly `now` into `out`. Must be called with
    /// monotonically non-decreasing `now`. `now` may jump forward by more
    /// than one cycle **provided no event is scheduled strictly inside the
    /// skipped interval** (jump to at most [`TimingWheel::next_event_at`]):
    /// every epoch boundary the jump crosses is cascaded on arrival, in
    /// order, so slot contents — and therefore same-cycle pop order — are
    /// bit-identical to cycle-by-cycle driving.
    pub fn pop_due(&mut self, now: u64, out: &mut Vec<T>) {
        let e = now / NEAR as u64;
        if self.len == 0 {
            // Empty wheel: every slot is empty, so cascading crossed
            // epochs and draining the near slot are both no-ops. Record
            // the epoch directly so later cycle-by-cycle driving does not
            // re-cascade boundaries this call already passed. With one
            // wheel per shard, most shards are empty most cycles — this
            // keeps their per-cycle cost at a compare and a store.
            self.epoch = self.epoch.max(e);
            return;
        }
        while self.epoch < e {
            self.epoch += 1;
            self.cascade(self.epoch * NEAR as u64);
        }
        let slot = (now % NEAR as u64) as usize;
        for (when, ev) in self.near[slot].drain(..) {
            debug_assert_eq!(when, now, "near slot holds only due events");
            self.len -= 1;
            out.push(ev);
        }
    }

    /// Remove every scheduled event matching `pred`, appending the removed
    /// `(when, event)` pairs to `out` in wheel-scan order: near slots
    /// 0..64, then far slots 0..64, then overflow, preserving in-slot
    /// insertion order. The wheel's slot layout is bit-identical across
    /// time-advance modes (see the module doc), so this order is
    /// deterministic for any single wheel — but it is *per wheel*:
    /// callers that extract across several sharded wheels and need one
    /// canonical sequence (the fault-injection drop pass) sort the
    /// collected `(when, event)` pairs themselves.
    pub fn extract_if<F: FnMut(&T) -> bool>(&mut self, mut pred: F, out: &mut Vec<(u64, T)>) {
        let before = out.len();
        for slot in self.near.iter_mut().chain(self.far.iter_mut()) {
            let mut i = 0;
            while i < slot.len() {
                if pred(&slot[i].1) {
                    out.push(slot.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        let mut i = 0;
        while i < self.overflow.len() {
            if pred(&self.overflow[i].1) {
                out.push(self.overflow.remove(i));
            } else {
                i += 1;
            }
        }
        self.len -= out.len() - before;
    }

    /// Epoch boundary: re-dispatch the current far slot (all its events fall
    /// inside the next 64 cycles) and any overflow events that have come
    /// within range of the two wheel levels.
    fn cascade(&mut self, now: u64) {
        let slot = ((now / NEAR as u64) % NEAR as u64) as usize;
        let due = std::mem::take(&mut self.far[slot]);
        for (when, ev) in due {
            debug_assert!(when >= now && when - now < NEAR as u64);
            self.place(now, when, ev);
        }
        if !self.overflow.is_empty() {
            let spill = std::mem::take(&mut self.overflow);
            for (when, ev) in spill {
                if when - now < (NEAR * NEAR) as u64 {
                    self.place(now, when, ev);
                } else {
                    self.overflow.push((when, ev));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the wheel from cycle `start`, collecting (cycle, event) pops.
    fn drain(w: &mut TimingWheel<u32>, start: u64, cycles: u64) -> Vec<(u64, u32)> {
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for now in start..start + cycles {
            buf.clear();
            w.pop_due(now, &mut buf);
            for &ev in &buf {
                got.push((now, ev));
            }
        }
        got
    }

    #[test]
    fn near_events_fire_on_time() {
        let mut w = TimingWheel::new();
        w.schedule(0, 1, 1u32);
        w.schedule(0, 63, 63);
        w.schedule(0, 5, 5);
        assert_eq!(w.len(), 3);
        let got = drain(&mut w, 0, 64);
        assert_eq!(got, vec![(1, 1), (5, 5), (63, 63)]);
        assert!(w.is_empty());
    }

    #[test]
    fn far_events_cascade_exactly_once() {
        let mut w = TimingWheel::new();
        // dt = 64 (the first value the old single-level wheel corrupted),
        // plus assorted points across the far range.
        for &when in &[64u64, 65, 100, 127, 128, 4095] {
            w.schedule(0, when, when as u32);
        }
        let got = drain(&mut w, 0, 4096);
        let want: Vec<(u64, u32)> = [64u64, 65, 100, 127, 128, 4095]
            .iter()
            .map(|&x| (x, x as u32))
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn overflow_events_survive_multiple_epochs() {
        let mut w = TimingWheel::new();
        w.schedule(0, 4096, 1u32); // exactly the overflow boundary
        w.schedule(0, 10_000, 2);
        w.schedule(0, 123_456, 3);
        let got = drain(&mut w, 0, 130_000);
        assert_eq!(got, vec![(4096, 1), (10_000, 2), (123_456, 3)]);
        assert!(w.is_empty());
    }

    #[test]
    fn scheduling_from_nonzero_now_and_mid_epoch() {
        let mut w = TimingWheel::new();
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for now in 1000..1500u64 {
            buf.clear();
            w.pop_due(now, &mut buf);
            for &ev in &buf {
                got.push((now, ev));
            }
            if now == 1001 {
                // Mid-epoch schedules landing in this and later epochs.
                w.schedule(now, 1002, 10);
                w.schedule(now, 1064, 11);
                w.schedule(now, 1065, 12);
                w.schedule(now, 1201, 13);
            }
        }
        assert_eq!(got, vec![(1002, 10), (1064, 11), (1065, 12), (1201, 13)]);
    }

    #[test]
    fn same_cycle_pops_in_insertion_order() {
        let mut w = TimingWheel::new();
        w.schedule(0, 10, 1u32);
        w.schedule(0, 10, 2);
        w.schedule(3, 10, 3);
        let got = drain(&mut w, 0, 16);
        assert_eq!(got, vec![(10, 1), (10, 2), (10, 3)]);
    }

    #[test]
    fn next_event_at_sees_all_three_levels() {
        let mut w: TimingWheel<u32> = TimingWheel::new();
        assert_eq!(w.next_event_at(), None);
        w.schedule(0, 10_000, 3); // overflow
        assert_eq!(w.next_event_at(), Some(10_000));
        w.schedule(0, 200, 2); // far
        assert_eq!(w.next_event_at(), Some(200));
        w.schedule(0, 5, 1); // near
        assert_eq!(w.next_event_at(), Some(5));
        let mut out = Vec::new();
        w.pop_due(5, &mut out); // jump straight to the nearest event
        assert_eq!(out, vec![1]);
        assert_eq!(w.next_event_at(), Some(200));
    }

    #[test]
    fn jumping_to_next_event_fires_every_level_exactly_once() {
        // Jumps land mid-epoch and cross many epoch boundaries at once —
        // including the far tier (100) and overflow tier (5000) that the
        // latency-5000 regression exercises cycle-by-cycle.
        let mut w = TimingWheel::new();
        for &when in &[3u64, 100, 4095, 5000, 123_456] {
            w.schedule(0, when, when as u32);
        }
        let mut got = Vec::new();
        let mut now = 0;
        let mut out = Vec::new();
        while let Some(t) = w.next_event_at() {
            assert!(t > now);
            now = t;
            out.clear();
            w.pop_due(now, &mut out);
            for &ev in &out {
                got.push((now, ev));
            }
            assert!(!got.is_empty(), "jump target must hold a due event");
        }
        let want: Vec<(u64, u32)> = [3u64, 100, 4095, 5000, 123_456]
            .iter()
            .map(|&x| (x, x as u32))
            .collect();
        assert_eq!(got, want);
        assert!(w.is_empty());
    }

    #[test]
    fn extract_if_removes_across_all_levels() {
        let mut w = TimingWheel::new();
        for &when in &[3u64, 10, 100, 5000, 123_456] {
            w.schedule(0, when, when as u32);
        }
        let mut out = Vec::new();
        // Pull the even-valued events, wherever they sit.
        w.extract_if(|&ev| ev % 2 == 0, &mut out);
        assert_eq!(out, vec![(10, 10u32), (100, 100), (5000, 5000), (123_456, 123_456)]);
        assert_eq!(w.len(), 1);
        // The survivor still fires on time.
        let got = drain(&mut w, 0, 16);
        assert_eq!(got, vec![(3, 3)]);
        assert!(w.is_empty());
    }

    /// Property (satellite of the adaptive time-advance PR, extended for
    /// the sharded-wheel PR): against a naive shadow scheduler (a flat
    /// `Vec` scanned linearly), `next_event_at` agrees at every step and
    /// pops deliver exactly the shadow's due set, across random schedules
    /// spanning all wheel levels (horizons up to ~6000 cycles cover near,
    /// far, and overflow — the latency-5000 regression territory), a
    /// random mix of single-cycle ticks and exact next-event jumps, and
    /// random `extract_if` passes interleaved mid-flight the way the
    /// fault-injection drop path fires them.
    #[test]
    fn next_event_at_matches_naive_scan() {
        crate::testing::check("wheel vs naive scheduler", 48, |rng| {
            let mut w: TimingWheel<u32> = TimingWheel::new();
            let mut shadow: Vec<(u64, u32)> = Vec::new();
            let mut now = 0u64;
            let mut id = 0u32;
            let mut out = Vec::new();
            let mut extracted = Vec::new();
            for _ in 0..300 {
                for _ in 0..rng.gen_range(4) {
                    let dt = 1 + rng.gen_range(6_000) as u64;
                    w.schedule(now, now + dt, id);
                    shadow.push((now + dt, id));
                    id += 1;
                }
                // Occasionally rip out a random residue class mid-flight —
                // the fault path's in-flight drop — and require the
                // extracted multiset (and the survivors, via the checks
                // below) to match the shadow. Events seeded into the
                // overflow tier (dt up to 6000) get extracted here too.
                if rng.gen_bool(0.15) {
                    let k = 2 + rng.gen_range(3) as u32;
                    let r = rng.gen_range(k as usize) as u32;
                    extracted.clear();
                    w.extract_if(|&ev| ev % k == r, &mut extracted);
                    let mut got = extracted.clone();
                    got.sort_unstable();
                    let mut want: Vec<(u64, u32)> = shadow
                        .iter()
                        .copied()
                        .filter(|&(_, i)| i % k == r)
                        .collect();
                    shadow.retain(|&(_, i)| i % k != r);
                    want.sort_unstable();
                    assert_eq!(got, want, "extract_if set mismatch at cycle {now}");
                }
                // The naive linear scan the wheel must agree with.
                let naive = shadow.iter().map(|&(t, _)| t).min();
                assert_eq!(w.next_event_at(), naive, "at cycle {now}");
                assert_eq!(w.len(), shadow.len());
                // Advance: a plain tick, or an exact jump to the next event
                // (the adaptive fast-path contract: never skip *past* one).
                now = if rng.gen_bool(0.5) {
                    naive.map_or(now + 1, |t| t.max(now + 1))
                } else {
                    now + 1
                };
                out.clear();
                w.pop_due(now, &mut out);
                let mut want: Vec<u32> = shadow
                    .iter()
                    .filter(|&&(t, _)| t == now)
                    .map(|&(_, i)| i)
                    .collect();
                shadow.retain(|&(t, _)| t != now);
                debug_assert!(shadow.iter().all(|&(t, _)| t > now));
                let mut got = out.clone();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "due set mismatch at cycle {now}");
            }
        });
    }
}
