//! Packet records. Flits are not separate heap objects: each packet carries
//! its flit count and the switch/link models account for serialization time
//! (one flit per link per cycle), which reproduces virtual-cut-through
//! timing at a fraction of the memory traffic (see DESIGN.md).

/// Dense packet id into the [`PacketArena`].
pub type PacketId = u32;

pub const NO_SWITCH: u32 = u32::MAX;

/// Sentinel for packets that belong to no application message (per-packet
/// workloads: fixed bursts, Bernoulli, kernels). Message-granular
/// workloads (`traffic::flows`) assign dense ids starting at 0.
pub const NO_MESSAGE: u32 = u32::MAX;

/// One in-flight packet.
#[derive(Clone, Debug)]
pub struct Packet {
    /// Source / destination servers (global server ids).
    pub src_server: u32,
    pub dst_server: u32,
    /// Source / destination switches.
    pub src_sw: u32,
    pub dst_sw: u32,
    /// Valiant-style intermediate switch chosen by the router
    /// (NO_SWITCH if none / not chosen yet).
    pub intermediate: u32,
    /// Switch-to-switch hops taken so far (`u16`: long-diameter service
    /// topologies such as `Path` on n > 256 switches exceed a `u8` bound).
    pub hops: u16,
    /// Virtual channel the packet currently occupies.
    pub vc: u8,
    /// Router-owned scratch state (a packet is handled by exactly one
    /// routing algorithm): TERA caches its port commitment as
    /// `(switch << 16) | (port + 1)` — 16-bit fields, so the tag survives
    /// n > 256 switches and ≥ 255-port switches; link orderings store
    /// `label + 1` of the last arc taken (0 = none yet); the 2D-HyperX
    /// routers store per-dimension progress bit flags.
    pub scratch: u32,
    /// Consecutive allocation attempts the packet has spent blocked at the
    /// head of its FIFO (reset on every grant). Escape-based routers take
    /// their service escape only after sustained blocking — the selection-
    /// function analogue of Duato-style escape channels.
    pub blocked: u16,
    /// Cycle the packet was generated (source queue entry).
    pub gen_cycle: u64,
    /// Cycle the packet entered the network (left the source queue).
    pub inject_cycle: u64,
    /// Flits in the packet (16 throughout the paper).
    pub flits: u16,
    /// Application message this packet belongs to ([`NO_MESSAGE`] for
    /// per-packet workloads). Carried end-to-end and handed back to the
    /// workload on delivery, so the flow layer can detect message
    /// completion and record FCT (`metrics::fct`).
    pub msg: u32,
}

/// Slab allocator for packets — no per-packet heap allocation in the
/// steady state; freed slots are recycled through a free list.
pub struct PacketArena {
    slots: Vec<Packet>,
    free: Vec<PacketId>,
    live: usize,
}

impl PacketArena {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            slots: Vec::with_capacity(cap),
            free: Vec::new(),
            live: 0,
        }
    }

    pub fn alloc(&mut self, p: Packet) -> PacketId {
        self.live += 1;
        if let Some(id) = self.free.pop() {
            self.slots[id as usize] = p;
            id
        } else {
            self.slots.push(p);
            (self.slots.len() - 1) as PacketId
        }
    }

    pub fn free(&mut self, id: PacketId) {
        debug_assert!(self.live > 0);
        self.live -= 1;
        self.free.push(id);
    }

    #[inline]
    pub fn get(&self, id: PacketId) -> &Packet {
        &self.slots[id as usize]
    }

    #[inline]
    pub fn get_mut(&mut self, id: PacketId) -> &mut Packet {
        &mut self.slots[id as usize]
    }

    /// Packets currently allocated (in flight somewhere in the network).
    pub fn live(&self) -> usize {
        self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(src: u32, dst: u32) -> Packet {
        Packet {
            src_server: src,
            dst_server: dst,
            src_sw: 0,
            dst_sw: 1,
            intermediate: NO_SWITCH,
            hops: 0,
            vc: 0,
            scratch: 0,
            blocked: 0,
            gen_cycle: 0,
            inject_cycle: 0,
            flits: 16,
            msg: NO_MESSAGE,
        }
    }

    #[test]
    fn arena_reuses_slots() {
        let mut a = PacketArena::with_capacity(4);
        let p1 = a.alloc(mk(0, 1));
        let p2 = a.alloc(mk(2, 3));
        assert_eq!(a.live(), 2);
        a.free(p1);
        assert_eq!(a.live(), 1);
        let p3 = a.alloc(mk(4, 5));
        assert_eq!(p3, p1, "slot should be recycled");
        assert_eq!(a.get(p3).src_server, 4);
        assert_eq!(a.get(p2).src_server, 2);
    }
}
