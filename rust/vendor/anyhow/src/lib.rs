//! Offline-compatible subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the pieces of `anyhow` the workspace actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics follow upstream `anyhow`:
//!
//! * `Error` is a dynamic error with an optional context chain;
//! * `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what lets the blanket `From<E: std::error::Error>` conversion coexist
//!   with the reflexive `From<Error>` (the same coherence trick upstream
//!   uses);
//! * `{:#}` (alternate `Display`) renders the full cause chain inline;
//!   `Debug` renders it as a `Caused by:` list (what `fn main() ->
//!   Result<()>` prints on error).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    /// A free-standing message (`anyhow!`, `bail!`, `Option` context).
    Msg(String),
    /// A wrapped concrete error.
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    /// A context layer over an inner error.
    Context { msg: String, source: Box<Error> },
}

/// A dynamic error type with a context chain.
pub struct Error {
    repr: Repr,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            repr: Repr::Msg(message.to_string()),
        }
    }

    /// Wrap a concrete error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error {
            repr: Repr::Boxed(Box::new(error)),
        }
    }

    /// Add a context layer (outermost message wins in `Display`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            repr: Repr::Context {
                msg: context.to_string(),
                source: Box::new(self),
            },
        }
    }

    /// The messages of every layer, outermost first.
    fn layers(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = self;
        loop {
            match &cur.repr {
                Repr::Msg(m) => {
                    out.push(m.clone());
                    return out;
                }
                Repr::Boxed(e) => {
                    out.push(e.to_string());
                    let mut src = e.source();
                    while let Some(s) = src {
                        out.push(s.to_string());
                        src = s.source();
                    }
                    return out;
                }
                Repr::Context { msg, source } => {
                    out.push(msg.clone());
                    cur = source;
                }
            }
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let layers = self.layers();
        if f.alternate() {
            write!(f, "{}", layers.join(": "))
        } else {
            write!(f, "{}", layers[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let layers = self.layers();
        write!(f, "{}", layers[0])?;
        if layers.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &layers[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

mod private {
    /// Sealed conversion into [`crate::Error`]: implemented for every
    /// `std::error::Error` *and* for `Error` itself, so `.context()` works on
    /// both `Result<T, E>` and `anyhow::Result<T>`.
    pub trait ToError {
        fn to_error(self) -> crate::Error;
    }
}
use private::ToError;

impl<E: StdError + Send + Sync + 'static> ToError for E {
    fn to_error(self) -> Error {
        Error::new(self)
    }
}

impl ToError for Error {
    fn to_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ToError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.to_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.to_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e = Error::new(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_works_on_result_option_and_anyhow_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let o: Option<i32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");

        let a: Result<()> = Err(anyhow!("inner"));
        let e = a.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
    }
}
