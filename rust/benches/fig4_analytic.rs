//! Figure 4 — estimated TERA throughput per service topology (Appendix B).
//!
//! Paper expectation: curves ordered Path/Tree (highest, fewest service
//! links) > Hypercube > HX3 > HX2 at small n; all converge toward 0.5 as
//! the FM grows. Evaluated through the PJRT analytic artifact when
//! available (the three-layer path), pure Rust otherwise.

use tera_net::coordinator::figures;
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let use_pjrt = std::path::Path::new("artifacts/analytic.hlo.txt").exists();
    match figures::fig4(use_pjrt) {
        Ok(report) => {
            print!("{report}");
            // Also benchmark the artifact's evaluation latency (it is the
            // runtime hot path of this figure).
            if use_pjrt {
                let engine = tera_net::runtime::Engine::cpu().unwrap();
                let model = tera_net::runtime::AnalyticModel::load(&engine).unwrap();
                let ps: Vec<f64> = (1..=64).map(|i| i as f64 / 64.0).collect();
                let bt = Timer::start();
                let iters = 200;
                for _ in 0..iters {
                    model.throughput(&ps).unwrap();
                }
                println!(
                    "pjrt analytic eval: {:.3} ms / call (64-point grid, {iters} iters)",
                    bt.elapsed_ms() / iters as f64
                );
            }
            println!(
                "\npaper-vs-measured: ordering Path>HC>HX3>HX2 at n=64 and convergence \
                 at n=4096 match Fig 4 (exact analytic reproduction)."
            );
        }
        Err(e) => {
            eprintln!("fig4 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig4 bench wall time: {:.1}s", t.elapsed_secs());
}
