//! Figure 8 — application-kernel completion times (All2All, Stencil 2D/3D,
//! FFT3D, Rabenseifner All-reduce).
//!
//! Paper expectations (§6.4): Omni-WAR best overall (2 VCs, unrestricted
//! non-minimal bandwidth; ~10% ahead on the stencils); TERA-HX2/HX3 within
//! ~7% of Omni-WAR on average despite using a single VC; TERA beats UGAL
//! clearly (up to ~47% on All-reduce).

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig8(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.4):\n\
                 [shape 1] Omni-WAR fastest or tied on every kernel\n\
                 [shape 2] TERA trails Omni-WAR by a small margin (paper: ≤~7%)\n\
                 [shape 3] TERA beats UGAL, largest gap on Allreduce\n\
                 [shape 4] MIN competitive only on neighbor-local stencils"
            );
        }
        Err(e) => {
            eprintln!("fig8 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig8 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
