//! Figure 5 — link-ordering schemes under fixed generation
//! (shift / complement / RSP bursts).
//!
//! Paper expectations (§6.1): sRINR ≤ bRINR completion time everywhere
//! (~9× faster on shift, ~3.8× on RSP); complement is the worst case for
//! both orderings (> 2.3× Valiant); Valiant is the best of the
//! non-minimal baselines on these adversarial patterns (at 2× the buffer
//! cost). Set FULL=1 for the paper-scale FM64 × 64 servers × 1250 pkts.

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig5(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.1):\n\
                 [shape 1] sRINR faster than bRINR on shift (paper: ~9x)\n\
                 [shape 2] sRINR faster than bRINR on RSP (paper: ~3.8x)\n\
                 [shape 3] complement is the hardest pattern for both orderings\n\
                 [shape 4] Valiant beats both orderings on complement (2 VCs)"
            );
        }
        Err(e) => {
            eprintln!("fig5 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig5 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
