//! Figure 7 — Bernoulli traffic: accepted throughput, latency, hop
//! distribution and Jain index vs offered load (UN + RSP).
//!
//! Paper expectations (§6.3): under UN all algorithms perform similarly
//! (80–90% minimal paths; Omni-WAR/UGAL marginally ahead thanks to the
//! second VC); under RSP the ordering is Omni-WAR > TERA-HX3 > Valiant >
//! TERA-HX2 > UGAL > sRINR, TERA beating sRINR by ~80%; TERA's 3/4-hop
//! share stays below ~1%.

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig7(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.3):\n\
                 [shape 1] UN: all algorithms within a few % of each other, >80% 1-hop\n\
                 [shape 2] RSP: Omni-WAR/TERA-HX3 lead; sRINR saturates far below TERA\n\
                 [shape 3] TERA 3+hop share < 1%\n\
                 [shape 4] Jain ≈ 1.0 under UN for all; degrades at saturation"
            );
        }
        Err(e) => {
            eprintln!("fig7 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig7 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
