//! Figure 9 — packet-latency distributions per application kernel
//! (violin densities exported to bench_out/fig9_violin.csv; the table
//! reports mean / p99 / p99.9 / p99.99 / max).
//!
//! Paper expectations (§6.4): TERA-HX2/HX3 lowest mean and p99 in most
//! kernels (less buffering → shorter queues); UGAL consistently the worst
//! tail (single random Valiant candidate); at p99.9+ TERA stays on top
//! except Stencil3D where it matches Omni-WAR.

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig9(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.4, Fig 9):\n\
                 [shape 1] TERA lowest mean/p99 in most kernels\n\
                 [shape 2] UGAL highest latency across the board\n\
                 [shape 3] violin densities written to bench_out/fig9_violin.csv"
            );
        }
        Err(e) => {
            eprintln!("fig9 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig9 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
