//! Figure 10 — 2D-HyperX evaluation: All2All and All-reduce completion
//! under DOR-TERA-HX3 (1 VC), O1TURN-TERA-HX3 (2 VCs), Dim-WAR (2 VCs),
//! Omni-WAR (4 VCs).
//!
//! Paper expectations (§6.5): DOR-TERA competitive with minimal resources;
//! O1TURN-TERA near Omni-WAR at half the buffers and up to ~32% better
//! than Dim-WAR at equal buffers.

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig10(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.5):\n\
                 [shape 1] DOR-TERA (1 VC) within striking distance of the rest\n\
                 [shape 2] O1TURN-TERA (2 VCs) ≈ Omni-WAR (4 VCs)\n\
                 [shape 3] O1TURN-TERA ≤ Dim-WAR at the same VC count"
            );
        }
        Err(e) => {
            eprintln!("fig10 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig10 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
