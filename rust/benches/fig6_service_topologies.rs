//! Figure 6 — TERA service-topology selection (RSP + FR bursts, FM size
//! sweep).
//!
//! Paper expectations (§6.2): under RSP the Path service is fastest (most
//! main links) and HX2 slowest, with the gap narrowing as n grows; under
//! FR the asymmetric services (Path, 4-Tree) collapse — their root/center
//! bottlenecks dominate — making the symmetric HyperX family the overall
//! choice.

use tera_net::coordinator::figures::{self, FigEnv, Scale};
use tera_net::util::Timer;

fn main() {
    let t = Timer::start();
    let scale = Scale::from_env(false);
    match figures::fig6(&FigEnv::ephemeral(scale, 1)) {
        Ok(report) => {
            print!("{report}");
            println!(
                "\npaper-vs-measured checklist (§6.2):\n\
                 [shape 1] RSP: Path fastest, HX2 slowest, gap narrows with n\n\
                 [shape 2] FR: Path/Tree4 worst (asymmetry), HyperX family robust\n\
                 [shape 3] HX2/HX3 close to Path on RSP at the largest size"
            );
        }
        Err(e) => {
            eprintln!("fig6 failed: {e:#}");
            std::process::exit(1);
        }
    }
    println!("fig6 bench wall time: {:.1}s ({scale:?})", t.elapsed_secs());
}
