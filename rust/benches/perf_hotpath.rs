//! §Perf — hot-path micro/macro benchmarks for the L3 simulator.
//!
//! Reports:
//!   * **idle-heavy** simulated Mcycles/s on a low-load fm32 sweep — the
//!     active-set engine's headline case: most switches idle most cycles,
//!     and idle components must cost zero (DESIGN.md, "Active-set
//!     invariants"). This is the number the active-set refactor is gated
//!     on (≥ 2× over the scan-everything engine);
//!   * saturated Mcycles/s and packet throughput of `Network::step` on the
//!     Fig-7 RSP workload (the end-to-end hot path);
//!   * routing decisions/second per algorithm (allocation inner loop);
//!   * PJRT batched-scorer latency (the artifact decision path, `pjrt`
//!     builds only).
//!
//! Before/after numbers across optimization iterations are recorded in
//! DESIGN.md §Perf.

use std::sync::Arc;

use tera_net::config::spec::{routing_by_name, topology_by_name, ExperimentSpec, TrafficSpec};
use tera_net::engine::Engine;
use tera_net::sim::{Network, RunOpts, SimConfig};
use tera_net::util::Timer;

fn bernoulli_spec(
    topo: &str,
    spc: usize,
    routing: &str,
    pattern: &str,
    load: f64,
    horizon: u64,
) -> ExperimentSpec {
    ExperimentSpec {
        name: format!("perf-{routing}-{load}"),
        topology: topo.into(),
        servers_per_switch: spc,
        routing: routing.into(),
        traffic: TrafficSpec::Bernoulli {
            pattern: pattern.into(),
            load,
            horizon,
        },
        warmup: 0,
        seed: 7,
        ..Default::default()
    }
}

/// Simulated Mcycles/s and delivered packets/s of one spec, single thread.
fn sim_throughput(spec: &ExperimentSpec) -> (f64, f64) {
    let TrafficSpec::Bernoulli { horizon, .. } = &spec.traffic else {
        panic!("perf specs are Bernoulli");
    };
    let cycles = *horizon as f64;
    let engine = Engine::single_threaded();
    let t = Timer::start();
    let stats = engine.run_one(spec).expect("run");
    let wall = t.elapsed_secs();
    (cycles / wall / 1e6, stats.delivered_packets as f64 / wall)
}

fn decision_rate(routing: &str) -> f64 {
    // Drive the router in a saturated network and count allocation-cycle
    // work indirectly via wall time per simulated cycle at high load.
    let topo = Arc::new(topology_by_name("fm64").unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let cfg = SimConfig {
        servers_per_switch: 16,
        seed: 3,
        ..SimConfig::default()
    };
    let mut net = Network::new(topo, router, cfg);
    let mut workload = ExperimentSpec {
        topology: "fm64".into(),
        servers_per_switch: 16,
        traffic: TrafficSpec::Bernoulli {
            pattern: "rsp".into(),
            load: 1.0,
            horizon: 6_000,
        },
        seed: 3,
        ..Default::default()
    }
    .build_workload(&net.topo)
    .unwrap();
    let t = Timer::start();
    let stats = net
        .run(
            workload.as_mut(),
            &RunOpts {
                max_cycles: 6_000,
                warmup: 0,
                window: None,
                stop_when_drained: false,
            },
        )
        .expect("run");
    // Approximate decisions by delivered hops (each hop = ≥1 grant).
    let hops: f64 = stats.delivered_packets as f64 * stats.mean_hops().max(1.0);
    hops / t.elapsed_secs()
}

fn main() {
    // ---- Idle-heavy: the active-set acceptance workload. ----
    // fm32 × 8 servers at very low uniform load: a handful of packets in
    // flight, the overwhelming majority of the 32 switches idle on any
    // given cycle. Wall time here is dominated by per-cycle fixed costs.
    println!("== idle-heavy low-load sweep (fm32 × 8 srv/sw, uniform) ==\n");
    println!("{:<8} {:>12} {:>14}", "load", "Mcycles/s", "delivered pkt/s");
    let horizon = 300_000u64;
    for load in [0.01, 0.02, 0.05, 0.10] {
        let spec = bernoulli_spec("fm32", 8, "tera-hx2", "uniform", load, horizon);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{load:<8} {mcps:>12.3} {pps:>14.0}");
    }

    // ---- Saturated end-to-end hot path (Fig-7 shape). ----
    println!("\n== saturated hot path (fm64 × 16 srv/sw, RSP 0.7) ==\n");
    println!(
        "{:<12} {:>12} {:>16}",
        "routing", "Mcycles/s", "delivered pkt/s"
    );
    let hz = 12_000u64;
    for r in ["min", "srinr", "tera-hx2", "ugal", "omniwar", "valiant"] {
        let spec = bernoulli_spec("fm64", 16, r, "rsp", 0.7, hz);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{r:<12} {mcps:>12.3} {pps:>16.0}");
    }

    println!("\nrouting decision throughput (saturated RSP):");
    for r in ["min", "srinr", "tera-hx2", "omniwar"] {
        let d = decision_rate(r);
        println!("  {r:<12} {:>12.2} M grants/s", d / 1e6);
    }

    // PJRT batched scorer (decision path through the artifact).
    if cfg!(feature = "pjrt") && std::path::Path::new("artifacts/tera_score.hlo.txt").exists() {
        use tera_net::runtime::{Engine as PjrtEngine, ScoreBatch, TeraScorer};
        let engine = PjrtEngine::cpu().unwrap();
        let scorer = TeraScorer::load(&engine).unwrap();
        let mut b = ScoreBatch::zeros(TeraScorer::BATCH, TeraScorer::PORTS, 54.0);
        for i in 0..b.occ.len() {
            b.occ[i] = (i % 97) as f32;
            b.valid[i] = 1.0;
            b.direct[i] = f32::from(i % 63 == 0);
        }
        let t = Timer::start();
        let iters = 500;
        for _ in 0..iters {
            scorer.score(&b).unwrap();
        }
        let per_call_ms = t.elapsed_ms() / iters as f64;
        println!(
            "\npjrt tera_score: {per_call_ms:.3} ms / 64-switch batch \
             ({:.2} M decisions/s)",
            (TeraScorer::BATCH as f64 / (per_call_ms / 1e3)) / 1e6
        );
    } else {
        println!("\n(pjrt scorer skipped: needs --features pjrt and `make artifacts`)");
    }
}
