//! §Perf — hot-path micro/macro benchmarks for the L3 simulator.
//!
//! Reports:
//!   * simulated Mcycles/s and packet-throughput of `Network::step` on the
//!     Fig-7 RSP workload (the end-to-end hot path);
//!   * routing decisions/second per algorithm (allocation inner loop);
//!   * PJRT batched-scorer latency (the artifact decision path).
//!
//! Before/after numbers across optimization iterations are recorded in
//! EXPERIMENTS.md §Perf.

use std::sync::Arc;

use tera_net::config::spec::{topology_by_name, routing_by_name, ExperimentSpec, TrafficSpec};
use tera_net::sim::{Network, RunOpts, SimConfig};
use tera_net::util::Timer;

fn sim_throughput(routing: &str, load: f64, pattern: &str) -> (f64, f64) {
    let horizon = 12_000u64;
    let spec = ExperimentSpec {
        name: format!("perf-{routing}"),
        topology: "fm64".into(),
        servers_per_switch: 16,
        routing: routing.into(),
        traffic: TrafficSpec::Bernoulli {
            pattern: pattern.into(),
            load,
            horizon,
        },
        warmup: 0,
        seed: 7,
        ..Default::default()
    };
    let t = Timer::start();
    let stats = spec.run().expect("run");
    let wall = t.elapsed_secs();
    let mcps = horizon as f64 / wall / 1e6;
    let pkts_per_sec = stats.delivered_packets as f64 / wall;
    (mcps, pkts_per_sec)
}

fn decision_rate(routing: &str) -> f64 {
    // Drive the router in a saturated network and count allocation-cycle
    // work indirectly via wall time per simulated cycle at high load.
    let topo = Arc::new(topology_by_name("fm64").unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let cfg = SimConfig {
        servers_per_switch: 16,
        seed: 3,
        ..SimConfig::default()
    };
    let mut net = Network::new(topo, router, cfg);
    let mut workload = ExperimentSpec {
        topology: "fm64".into(),
        servers_per_switch: 16,
        traffic: TrafficSpec::Bernoulli {
            pattern: "rsp".into(),
            load: 1.0,
            horizon: 6_000,
        },
        seed: 3,
        ..Default::default()
    }
    .build_workload(&net.topo)
    .unwrap();
    let t = Timer::start();
    let stats = net
        .run(
            workload.as_mut(),
            &RunOpts {
                max_cycles: 6_000,
                warmup: 0,
                window: None,
                stop_when_drained: false,
            },
        )
        .expect("run");
    // Approximate decisions by delivered hops (each hop = ≥1 grant).
    let hops: f64 = stats.delivered_packets as f64 * stats.mean_hops().max(1.0);
    hops / t.elapsed_secs()
}

fn main() {
    println!("== §Perf hot-path benchmarks (fm64 × 16 srv/sw) ==\n");
    println!(
        "{:<12} {:>12} {:>16}",
        "routing", "Mcycles/s", "delivered pkt/s"
    );
    for r in ["min", "srinr", "tera-hx2", "ugal", "omniwar", "valiant"] {
        let (mcps, pps) = sim_throughput(r, 0.7, "rsp");
        println!("{r:<12} {mcps:>12.3} {pps:>16.0}");
    }

    println!("\nrouting decision throughput (saturated RSP):");
    for r in ["min", "srinr", "tera-hx2", "omniwar"] {
        let d = decision_rate(r);
        println!("  {r:<12} {:>12.2} M grants/s", d / 1e6);
    }

    // PJRT batched scorer (decision path through the artifact).
    if std::path::Path::new("artifacts/tera_score.hlo.txt").exists() {
        use tera_net::runtime::{Engine, ScoreBatch, TeraScorer};
        let engine = Engine::cpu().unwrap();
        let scorer = TeraScorer::load(&engine).unwrap();
        let mut b = ScoreBatch::zeros(TeraScorer::BATCH, TeraScorer::PORTS, 54.0);
        for i in 0..b.occ.len() {
            b.occ[i] = (i % 97) as f32;
            b.valid[i] = 1.0;
            b.direct[i] = f32::from(i % 63 == 0);
        }
        let t = Timer::start();
        let iters = 500;
        for _ in 0..iters {
            scorer.score(&b).unwrap();
        }
        let per_call_ms = t.elapsed_ms() / iters as f64;
        println!(
            "\npjrt tera_score: {per_call_ms:.3} ms / 64-switch batch \
             ({:.2} M decisions/s)",
            (TeraScorer::BATCH as f64 / (per_call_ms / 1e3)) / 1e6
        );
    } else {
        println!("\n(pjrt scorer skipped: run `make artifacts`)");
    }
}
