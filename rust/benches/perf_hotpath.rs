//! §Perf — hot-path micro/macro benchmarks for the L3 simulator.
//!
//! Reports:
//!   * **idle-heavy** simulated Mcycles/s on a low-load fm32 sweep — the
//!     active-set engine's headline case: most switches idle most cycles,
//!     and idle components must cost zero (DESIGN.md, "Active-set
//!     invariants"). This is the number the active-set refactor is gated
//!     on (≥ 2× over the scan-everything engine);
//!   * **routing-table build cost** and **route throughput**: time to
//!     compile the `RoutingTables`/`HxTables` layer, then raw
//!     `Router::route` decisions/s driven over synthetic switch views on
//!     FM64 and HX[8x8] — with a counting global allocator asserting
//!     ZERO heap allocations across the measured decisions (the
//!     table-driven-core acceptance gate);
//!   * saturated Mcycles/s and packet throughput of `Network::step` on the
//!     Fig-7 RSP workload (the end-to-end hot path);
//!   * routing decisions/second per algorithm (allocation inner loop);
//!   * PJRT batched-scorer latency (the artifact decision path, `pjrt`
//!     builds only).
//!
//! Before/after numbers across optimization iterations are recorded in
//! DESIGN.md §Perf.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tera_net::config::spec::{routing_by_name, topology_by_name, ExperimentSpec, TrafficSpec};
use tera_net::engine::Engine;
use tera_net::routing::{CandidateBuf, HxTables, RoutingTables};
use tera_net::service::{HyperXService, ServiceTopology};
use tera_net::sim::packet::{Packet, NO_SWITCH};
use tera_net::sim::{Network, RunOpts, SimConfig, SwitchView};
use tera_net::topology::TopoKind;
use tera_net::util::{Rng, Timer};

/// Counting allocator: wraps the system allocator and counts allocation
/// events, so the route-throughput section can *prove* the zero-allocation
/// claim of the table-driven routing core rather than assert it in prose.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bernoulli_spec(
    topo: &str,
    spc: usize,
    routing: &str,
    pattern: &str,
    load: f64,
    horizon: u64,
) -> ExperimentSpec {
    ExperimentSpec {
        name: format!("perf-{routing}-{load}"),
        topology: topo.into(),
        servers_per_switch: spc,
        routing: routing.into(),
        traffic: TrafficSpec::Bernoulli {
            pattern: pattern.into(),
            load,
            horizon,
        },
        warmup: 0,
        seed: 7,
        ..Default::default()
    }
}

/// Simulated Mcycles/s and delivered flits of one spec through the free
/// build path, which honors `spec.shards` exactly (the engine would clamp
/// it to a thread budget). Used by the sharded-cycle-execution section.
fn sharded_throughput(spec: &ExperimentSpec) -> (f64, u64) {
    let TrafficSpec::Bernoulli { horizon, .. } = &spec.traffic else {
        panic!("perf specs are Bernoulli");
    };
    let mut net = tera_net::engine::build_network(spec).expect("build");
    let mut wl = spec.build_workload(&net.topo).expect("workload");
    let opts = tera_net::engine::run_opts(spec);
    let t = Timer::start();
    let stats = net.run(wl.as_mut(), &opts).expect("run");
    let wall = t.elapsed_secs();
    (*horizon as f64 / wall / 1e6, stats.delivered_flits)
}

/// Simulated Mcycles/s and delivered packets/s of one spec, single thread.
fn sim_throughput(spec: &ExperimentSpec) -> (f64, f64) {
    let TrafficSpec::Bernoulli { horizon, .. } = &spec.traffic else {
        panic!("perf specs are Bernoulli");
    };
    let cycles = *horizon as f64;
    let engine = Engine::single_threaded();
    let t = Timer::start();
    let stats = engine.run_one(spec).expect("run");
    let wall = t.elapsed_secs();
    (cycles / wall / 1e6, stats.delivered_packets as f64 / wall)
}

fn decision_rate(routing: &str) -> f64 {
    // Drive the router in a saturated network and count allocation-cycle
    // work indirectly via wall time per simulated cycle at high load.
    let topo = Arc::new(topology_by_name("fm64").unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let cfg = SimConfig {
        servers_per_switch: 16,
        seed: 3,
        ..SimConfig::default()
    };
    let mut net = Network::new(topo, router, cfg);
    let mut workload = ExperimentSpec {
        topology: "fm64".into(),
        servers_per_switch: 16,
        traffic: TrafficSpec::Bernoulli {
            pattern: "rsp".into(),
            load: 1.0,
            horizon: 6_000,
        },
        seed: 3,
        ..Default::default()
    }
    .build_workload(&net.topo)
    .unwrap();
    let t = Timer::start();
    let stats = net
        .run(
            workload.as_mut(),
            &RunOpts {
                max_cycles: 6_000,
                warmup: 0,
                window: None,
                stop_when_drained: false,
            },
        )
        .expect("run");
    // Approximate decisions by delivered hops (each hop = ≥1 grant).
    let hops: f64 = stats.delivered_packets as f64 * stats.mean_hops().max(1.0);
    hops / t.elapsed_secs()
}

/// Raw `Router::route` throughput over synthetic views: decisions/s plus
/// the number of allocator events observed across the measured window
/// (must be zero — candidate sets live in the reused `CandidateBuf`).
fn route_throughput(host: &str, routing: &str, iters: usize) -> (f64, u64) {
    let topo = Arc::new(topology_by_name(host).unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let n = topo.n;
    let vcs = router.num_vcs();
    let degree = topo.max_degree(); // FM and square HyperX are regular
    let spc = 8;
    let ports = degree + spc;
    let mut rng = Rng::new(0xBE7C);
    let occ: Vec<u32> = (0..ports).map(|i| ((i * 37) % 160) as u32).collect();
    let out_lens: Vec<u32> = (0..ports * vcs).map(|i| ((i * 13) % 5) as u32).collect();
    let grants = vec![0u8; ports];
    let last = vec![u64::MAX; ports];
    let mut pkt = Packet {
        src_server: 0,
        dst_server: 0,
        src_sw: 0,
        dst_sw: 1,
        intermediate: NO_SWITCH,
        hops: 0,
        vc: 0,
        scratch: 0,
        blocked: 0,
        gen_cycle: 0,
        inject_cycle: 0,
        flits: 16,
    };
    let is_hx = matches!(topo.kind, TopoKind::HyperX { .. });
    let mut buf = CandidateBuf::new();
    let mut sink = 0usize;
    let mut run = |iters: usize, rng: &mut Rng, sink: &mut usize| {
        for i in 0..iters {
            let s = i % n;
            let mut d = (i * 7 + 1) % n;
            if d == s {
                d = (d + 1) % n;
            }
            pkt.src_sw = s as u32;
            pkt.dst_sw = d as u32;
            pkt.intermediate = NO_SWITCH;
            pkt.hops = 0;
            pkt.blocked = 0;
            // Alternate injection/transit decisions to cover both paths.
            // The 2D-HyperX routers track transit through scratch bits
            // (order chosen + both dimension hops taken) rather than the
            // `at_injection` flag.
            let transit = i % 2 == 1;
            let at_injection = if is_hx { true } else { !transit };
            pkt.scratch = if is_hx && transit { 0b111 } else { 0 };
            let view = SwitchView::from_raw(
                s, degree, 1, 2, vcs, 5, &occ, &out_lens, &grants, &last,
            );
            if let Some((p, _vc)) = router.route(&view, &mut pkt, at_injection, rng, &mut buf) {
                *sink += p;
            }
        }
    };
    // Warmup grows the candidate buffer to its steady-state capacity.
    run(2_000, &mut rng, &mut sink);
    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
    let t = Timer::start();
    run(iters, &mut rng, &mut sink);
    let secs = t.elapsed_secs();
    let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
    std::hint::black_box(sink);
    (iters as f64 / secs, allocs)
}

fn main() {
    // ---- Routing-table build + route throughput (table-driven core). ----
    println!("== routing tables: build cost + route throughput ==\n");
    {
        let t = Timer::start();
        let fm = Arc::new(topology_by_name("fm64").unwrap());
        let svc: Arc<dyn ServiceTopology> = Arc::new(HyperXService::square(64).unwrap());
        let tables = RoutingTables::compile(fm, Some(svc));
        println!(
            "build fm64 + hx2 service   {:>8.3} ms (p = {:.3})",
            t.elapsed_ms(),
            tables.main_ratio()
        );
        let t = Timer::start();
        let hx_host = Arc::new(topology_by_name("hx8x8").unwrap());
        let sub: Arc<dyn ServiceTopology> = Arc::new(HyperXService::hypercube(8).unwrap());
        let hx = HxTables::with_service(hx_host, sub);
        println!(
            "build hx8x8 per-dim tables {:>8.3} ms (sub-diameter {})",
            t.elapsed_ms(),
            hx.sub_diameter()
        );
        let t = Timer::start();
        let fm300 = Arc::new(topology_by_name("fm300").unwrap());
        let _tables300 = RoutingTables::compile(fm300, None);
        println!("build fm300 min-port only  {:>8.3} ms", t.elapsed_ms());
    }
    println!();
    println!("{:<22} {:>14} {:>12}", "router@host", "Mdecisions/s", "allocs");
    let iters = 2_000_000;
    for (host, routing) in [
        ("fm64", "tera-hx2"),
        ("fm64", "srinr"),
        ("fm64", "min"),
        ("hx8x8", "dor-tera"),
        ("hx8x8", "o1turn-tera"),
    ] {
        let (dps, allocs) = route_throughput(host, routing, iters);
        println!("{:<22} {:>14.2} {:>12}", format!("{routing}@{host}"), dps / 1e6, allocs);
        assert_eq!(
            allocs, 0,
            "{routing}@{host}: Router::route allocated on the hot path"
        );
    }
    println!("zero-allocation route path: VERIFIED (counting allocator)\n");

    // ---- Idle-heavy: the active-set acceptance workload. ----
    // fm32 × 8 servers at very low uniform load: a handful of packets in
    // flight, the overwhelming majority of the 32 switches idle on any
    // given cycle. Wall time here is dominated by per-cycle fixed costs.
    println!("== idle-heavy low-load sweep (fm32 × 8 srv/sw, uniform) ==\n");
    println!("{:<8} {:>12} {:>14}", "load", "Mcycles/s", "delivered pkt/s");
    let horizon = 300_000u64;
    for load in [0.01, 0.02, 0.05, 0.10] {
        let spec = bernoulli_spec("fm32", 8, "tera-hx2", "uniform", load, horizon);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{load:<8} {mcps:>12.3} {pps:>14.0}");
    }

    // ---- Saturated end-to-end hot path (Fig-7 shape). ----
    println!("\n== saturated hot path (fm64 × 16 srv/sw, RSP 0.7) ==\n");
    println!(
        "{:<12} {:>12} {:>16}",
        "routing", "Mcycles/s", "delivered pkt/s"
    );
    let hz = 12_000u64;
    for r in ["min", "srinr", "tera-hx2", "ugal", "omniwar", "valiant"] {
        let spec = bernoulli_spec("fm64", 16, r, "rsp", 0.7, hz);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{r:<12} {mcps:>12.3} {pps:>16.0}");
    }

    println!("\nrouting decision throughput (saturated RSP):");
    for r in ["min", "srinr", "tera-hx2", "omniwar"] {
        let d = decision_rate(r);
        println!("  {r:<12} {:>12.2} M grants/s", d / 1e6);
    }

    // ---- Sharded cycle execution: one replica across cores (FM300). ----
    // The phase-parallel core partitions the switches into `--shards`
    // blocks simulated concurrently within each cycle; results are
    // bit-identical at any shard count (asserted below against the serial
    // run), so this section measures the pure wall-clock win on the
    // paper's FM300-class instance. Emits BENCH_shards.json as the
    // perf-trajectory artifact.
    println!("\n== sharded cycle execution (fm300 × 8 srv/sw, Bernoulli 0.35) ==\n");
    println!(
        "{:<12} {:>7} {:>12} {:>10}",
        "pattern", "shards", "Mcycles/s", "speedup"
    );
    let mut artifact = String::from(
        "{\n  \"bench\": \"sharded-cycle-execution\",\n  \"topology\": \"fm300\",\n  \
         \"routing\": \"tera-path\",\n  \"load\": 0.35,\n  \"results\": [\n",
    );
    let mut first = true;
    for pattern in ["uniform", "rsp"] {
        let mut base_mcps = 0.0f64;
        let mut base_flits = 0u64;
        for shards in [1usize, 2, 4, 8] {
            let mut spec = bernoulli_spec("fm300", 8, "tera-path", pattern, 0.35, 1_200);
            spec.shards = shards;
            let (mcps, flits) = sharded_throughput(&spec);
            if shards == 1 {
                base_mcps = mcps;
                base_flits = flits;
            } else {
                assert_eq!(
                    flits, base_flits,
                    "{pattern}@{shards} shards: determinism violated vs serial run"
                );
            }
            let speedup = mcps / base_mcps;
            println!("{pattern:<12} {shards:>7} {mcps:>12.3} {speedup:>9.2}x");
            if !first {
                artifact.push_str(",\n");
            }
            first = false;
            artifact.push_str(&format!(
                "    {{\"pattern\": \"{pattern}\", \"shards\": {shards}, \
                 \"mcycles_per_sec\": {mcps:.4}, \"speedup_vs_serial\": {speedup:.3}}}"
            ));
        }
    }
    artifact.push_str("\n  ]\n}\n");
    match std::fs::write("BENCH_shards.json", &artifact) {
        Ok(()) => println!("\nwrote BENCH_shards.json (sharded determinism: VERIFIED)"),
        Err(e) => println!("\ncould not write BENCH_shards.json: {e}"),
    }

    // PJRT batched scorer (decision path through the artifact).
    if cfg!(feature = "pjrt") && std::path::Path::new("artifacts/tera_score.hlo.txt").exists() {
        use tera_net::runtime::{Engine as PjrtEngine, ScoreBatch, TeraScorer};
        let engine = PjrtEngine::cpu().unwrap();
        let scorer = TeraScorer::load(&engine).unwrap();
        let mut b = ScoreBatch::zeros(TeraScorer::BATCH, TeraScorer::PORTS, 54.0);
        for i in 0..b.occ.len() {
            b.occ[i] = (i % 97) as f32;
            b.valid[i] = 1.0;
            b.direct[i] = f32::from(i % 63 == 0);
        }
        let t = Timer::start();
        let iters = 500;
        for _ in 0..iters {
            scorer.score(&b).unwrap();
        }
        let per_call_ms = t.elapsed_ms() / iters as f64;
        println!(
            "\npjrt tera_score: {per_call_ms:.3} ms / 64-switch batch \
             ({:.2} M decisions/s)",
            (TeraScorer::BATCH as f64 / (per_call_ms / 1e3)) / 1e6
        );
    } else {
        println!("\n(pjrt scorer skipped: needs --features pjrt and `make artifacts`)");
    }
}
