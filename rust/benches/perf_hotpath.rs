//! §Perf — hot-path micro/macro benchmarks for the L3 simulator.
//!
//! Reports:
//!   * **idle-heavy** simulated Mcycles/s on a low-load fm32 sweep — the
//!     active-set engine's headline case: most switches idle most cycles,
//!     and idle components must cost zero (DESIGN.md, "Active-set
//!     invariants"). This is the number the active-set refactor is gated
//!     on (≥ 2× over the scan-everything engine);
//!   * **routing-table build cost** and **route throughput**: time to
//!     compile the `RoutingTables`/`HxTables` layer, then raw
//!     `Router::route` vs `Router::route_batched` decisions/s per router,
//!     driven over synthetic switch views on FM64 and HX[8x8] — with a
//!     counting global allocator asserting ZERO heap allocations across
//!     the measured decisions in both modes (the table-driven-core
//!     acceptance gate). Per-router scalar/batched rows also land in
//!     **`BENCH_route.json`** (section `route`) for the perf gate;
//!   * **routing-table tiers**: flat vs compressed compile wall time and
//!     resident table bytes at FM300 / HX[8x8] / df65x16x8 (threaded
//!     compile; ≥10× memory reduction at the ~1k-switch Dragonfly
//!     asserted in-bench), plus the million-endpoint-class df2049x64x32
//!     point compressed-only on full runs — **`BENCH_tables.json`**
//!     (section `tables`);
//!   * **fault reconfiguration**: degraded-rebuild latency at the same
//!     three instance points — stop-the-world recompile vs incremental
//!     patch of the deroute overlay for a single-link transition (the
//!     patch asserted byte-equal to the recompile), plus end-to-end fm64
//!     runs with 2% of links failing mid-run under both rebuild
//!     strategies — **`BENCH_faults.json`** (section `faults`);
//!   * **batched hot path**: scalar vs batched compute-phase A/B on the
//!     saturated FM300 RSP point (`SimConfig::batched`), with delivered
//!     flits asserted equal — the gather/score/commit restructure's
//!     acceptance number (section `batched-fm300`);
//!   * **shard scaling**: per-shard timing wheels vs the `--global-wheel`
//!     A/B baseline on saturated FM300 and the palmtree df65x16x8, shards
//!     1..8 — parallel efficiency printed, delivered-flit equality
//!     asserted at every point, and the 4-shard sharded-wheel ≥ 1.5×
//!     speedup over the global wheel asserted on full runs with ≥ 4
//!     cores — **`BENCH_shards.json`** (section `shards`; rows land only
//!     there so the section is gated once);
//!   * saturated Mcycles/s and packet throughput of `Network::step` on the
//!     Fig-7 RSP workload (the end-to-end hot path);
//!   * routing decisions/second per algorithm (allocation inner loop);
//!   * **adaptive time advance** on a lull-heavy fm64 kernel (long-wire
//!     allreduce, most cycles dead) — wall-clock speedup and the
//!     cycles-ticked/cycles-covered ratio, with delivered-flit equality
//!     asserted against the fixed-tick run (`BENCH_adaptive.json`);
//!   * **statistical early termination** on an FM300 Bernoulli point —
//!     cycles and wall-clock saved at `stop_rel_ci = 0.05` vs the fixed
//!     horizon, with the achieved CI half-width (`BENCH_adaptive.json`);
//!   * **message/flow workloads** (incast, hotspot, closed-loop,
//!     multi-tenant on fm64) — end-to-end FCT-pipeline wall time and
//!     messages/s per scenario × routing, with the completion invariant
//!     asserted (`BENCH_flows.json`);
//!   * PJRT batched-scorer latency (the artifact decision path, `pjrt`
//!     builds only).
//!
//! Every section (bar shard scaling, which owns `BENCH_shards.json`) also
//! lands one row per measurement in **`BENCH_cycles.json`** (section,
//! label, wall seconds, cycles, cycles/s) — the consolidated
//! perf-trajectory baseline future PRs diff against; CI uploads all
//! `BENCH_*.json` as workflow artifacts and merges them into one
//! `bench_trajectory.json` (section → wall ms) per run.
//! `PERF_QUICK=1` shrinks horizons so CI finishes in seconds.
//!
//! Before/after numbers across optimization iterations are recorded in
//! DESIGN.md §Perf.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use tera_net::config::spec::{routing_by_name, topology_by_name, ExperimentSpec, TrafficSpec};
use tera_net::engine::Engine;
use tera_net::metrics::SimStats;
use tera_net::routing::{CandidateBuf, HxTables, RoutingTables, TableTier};
use tera_net::service::{DragonflyService, HyperXService, ServiceTopology};
use tera_net::sim::packet::{Packet, NO_SWITCH};
use tera_net::sim::{Network, RunOpts, SimConfig, SwitchView};
use tera_net::store::json::Json;
use tera_net::topology::{dragonfly, DeadSet, PhysTopology, TopoKind};
use tera_net::traffic::kernels::{allreduce_rabenseifner, KernelWorkload, Mapping};
use tera_net::traffic::FlowSpec;
use tera_net::util::{Rng, Timer};

/// `PERF_QUICK=1` (the CI artifact run) shrinks horizons and repetition
/// counts so the whole harness finishes in seconds; the JSON schema is
/// identical either way.
fn quick() -> bool {
    std::env::var("PERF_QUICK").map_or(false, |v| v == "1")
}

/// Consolidated per-section perf rows, flushed to `BENCH_cycles.json`:
/// the perf-trajectory baseline future PRs compare against. Built through
/// the store's [`Json`] encoder (the schema the CI gate parses is plain
/// JSON either way; the encoder just makes malformed rows unrepresentable).
struct CycleBench {
    rows: Vec<Json>,
}

impl CycleBench {
    fn new() -> Self {
        Self { rows: Vec::new() }
    }

    fn add(&mut self, section: &str, label: &str, wall_secs: f64, cycles: f64) {
        let cps = if wall_secs > 0.0 { cycles / wall_secs } else { 0.0 };
        self.rows.push(Json::obj([
            ("section", Json::Str(section.into())),
            ("label", Json::Str(label.into())),
            ("wall_secs", Json::Float(wall_secs)),
            ("cycles", Json::Float(cycles)),
            ("cycles_per_sec", Json::Float(cps)),
        ]));
    }

    fn write(&self) {
        let doc = Json::obj([
            ("bench", Json::Str("perf-hotpath-cycles".into())),
            ("quick", Json::Bool(quick())),
            ("results", Json::arr(self.rows.iter().cloned())),
        ]);
        match std::fs::write("BENCH_cycles.json", format!("{doc}\n")) {
            Ok(()) => println!("wrote BENCH_cycles.json ({} rows)", self.rows.len()),
            Err(e) => println!("could not write BENCH_cycles.json: {e}"),
        }
    }
}

/// Counting allocator: wraps the system allocator and counts allocation
/// events, so the route-throughput section can *prove* the zero-allocation
/// claim of the table-driven routing core rather than assert it in prose.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bernoulli_spec(
    topo: &str,
    spc: usize,
    routing: &str,
    pattern: &str,
    load: f64,
    horizon: u64,
) -> ExperimentSpec {
    ExperimentSpec {
        name: format!("perf-{routing}-{load}"),
        topology: topo.into(),
        servers_per_switch: spc,
        routing: routing.into(),
        traffic: TrafficSpec::Bernoulli {
            pattern: pattern.into(),
            load,
            horizon,
        },
        warmup: 0,
        seed: 7,
        ..Default::default()
    }
}

/// Simulated Mcycles/s and delivered flits of one spec through the free
/// build path, which honors `spec.shards` exactly (the engine would clamp
/// it to a thread budget). Used by the shard-scaling section.
fn sharded_throughput(spec: &ExperimentSpec) -> (f64, u64) {
    let TrafficSpec::Bernoulli { horizon, .. } = &spec.traffic else {
        panic!("perf specs are Bernoulli");
    };
    let mut net = tera_net::engine::build_network(spec).expect("build");
    let mut wl = spec.build_workload(&net.topo).expect("workload");
    let opts = tera_net::engine::run_opts(spec);
    let t = Timer::start();
    let stats = net.run(wl.as_mut(), &opts).expect("run");
    let wall = t.elapsed_secs();
    (*horizon as f64 / wall / 1e6, stats.delivered_flits)
}

/// Simulated Mcycles/s and delivered packets/s of one spec, single thread.
fn sim_throughput(spec: &ExperimentSpec) -> (f64, f64) {
    let TrafficSpec::Bernoulli { horizon, .. } = &spec.traffic else {
        panic!("perf specs are Bernoulli");
    };
    let cycles = *horizon as f64;
    let engine = Engine::single_threaded();
    let t = Timer::start();
    let stats = engine.run_one(spec).expect("run");
    let wall = t.elapsed_secs();
    (cycles / wall / 1e6, stats.delivered_packets as f64 / wall)
}

fn decision_rate(routing: &str) -> f64 {
    // Drive the router in a saturated network and count allocation-cycle
    // work indirectly via wall time per simulated cycle at high load.
    let topo = Arc::new(topology_by_name("fm64").unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let cfg = SimConfig {
        servers_per_switch: 16,
        seed: 3,
        ..SimConfig::default()
    };
    let mut net = Network::new(topo, router, cfg);
    let mut workload = ExperimentSpec {
        topology: "fm64".into(),
        servers_per_switch: 16,
        traffic: TrafficSpec::Bernoulli {
            pattern: "rsp".into(),
            load: 1.0,
            horizon: 6_000,
        },
        seed: 3,
        ..Default::default()
    }
    .build_workload(&net.topo)
    .unwrap();
    let t = Timer::start();
    let stats = net
        .run(
            workload.as_mut(),
            &RunOpts {
                max_cycles: 6_000,
                warmup: 0,
                window: None,
                stop_when_drained: false,
                ..RunOpts::default()
            },
        )
        .expect("run");
    // Approximate decisions by delivered hops (each hop = ≥1 grant).
    let hops: f64 = stats.delivered_packets as f64 * stats.mean_hops().max(1.0);
    hops / t.elapsed_secs()
}

/// Raw `Router::route` / `Router::route_batched` throughput over synthetic
/// views: decisions/s plus the number of allocator events observed across
/// the measured window (must be zero in either mode — candidate sets live
/// in the reused `CandidateBuf`).
fn route_throughput(host: &str, routing: &str, iters: usize, batched: bool) -> (f64, u64) {
    let topo = Arc::new(topology_by_name(host).unwrap());
    let router = routing_by_name(routing, topo.clone(), 54).unwrap();
    let n = topo.n;
    let vcs = router.num_vcs();
    let degree = topo.max_degree(); // FM and square HyperX are regular
    let spc = 8;
    let ports = degree + spc;
    let mut rng = Rng::new(0xBE7C);
    let occ: Vec<u32> = (0..ports).map(|i| ((i * 37) % 160) as u32).collect();
    let out_lens: Vec<u32> = (0..ports * vcs).map(|i| ((i * 13) % 5) as u32).collect();
    let grants = vec![0u8; ports];
    let last = vec![u64::MAX; ports];
    let mut pkt = Packet {
        src_server: 0,
        dst_server: 0,
        src_sw: 0,
        dst_sw: 1,
        intermediate: NO_SWITCH,
        hops: 0,
        vc: 0,
        scratch: 0,
        blocked: 0,
        gen_cycle: 0,
        inject_cycle: 0,
        flits: 16,
        msg: tera_net::sim::NO_MESSAGE,
    };
    let is_hx = matches!(topo.kind, TopoKind::HyperX { .. });
    let mut buf = CandidateBuf::new();
    let mut sink = 0usize;
    let mut run = |iters: usize, rng: &mut Rng, sink: &mut usize| {
        for i in 0..iters {
            let s = i % n;
            let mut d = (i * 7 + 1) % n;
            if d == s {
                d = (d + 1) % n;
            }
            pkt.src_sw = s as u32;
            pkt.dst_sw = d as u32;
            pkt.intermediate = NO_SWITCH;
            pkt.hops = 0;
            pkt.blocked = 0;
            // Alternate injection/transit decisions to cover both paths.
            // The 2D-HyperX routers track transit through scratch bits
            // (order chosen + both dimension hops taken) rather than the
            // `at_injection` flag.
            let transit = i % 2 == 1;
            let at_injection = if is_hx { true } else { !transit };
            pkt.scratch = if is_hx && transit { 0b111 } else { 0 };
            let view = SwitchView::from_raw(
                s, degree, 1, 2, vcs, 5, &occ, &out_lens, &grants, &last,
            );
            let decision = if batched {
                router.route_batched(&view, &mut pkt, at_injection, rng, &mut buf)
            } else {
                router.route(&view, &mut pkt, at_injection, rng, &mut buf)
            };
            if let Some((p, _vc)) = decision {
                *sink += p;
            }
        }
    };
    // Warmup grows the candidate buffer to its steady-state capacity.
    run(2_000, &mut rng, &mut sink);
    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
    let t = Timer::start();
    run(iters, &mut rng, &mut sink);
    let secs = t.elapsed_secs();
    let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
    std::hint::black_box(sink);
    (iters as f64 / secs, allocs)
}

/// One lull-heavy kernel run: a sparse 8-rank Rabenseifner allreduce on
/// fm64 with a long wire (`link_latency` cycles), so almost every covered
/// cycle is a dead synchronization stall. Returns accumulated
/// `(wall_secs, cycles_ticked, cycles_covered, delivered_flits)` over
/// `reps` repetitions (distinct seeds).
fn lull_kernel_run(
    time_skip: bool,
    link_latency: u64,
    reps: usize,
) -> (f64, u64, u64, u64) {
    let topo = Arc::new(topology_by_name("fm64").unwrap());
    let router = routing_by_name("tera-hx2", topo.clone(), 54).unwrap();
    let mut wall = 0.0;
    let (mut ticked, mut covered, mut delivered) = (0u64, 0u64, 0u64);
    for rep in 0..reps {
        let seed = 9 + rep as u64;
        let cfg = SimConfig {
            servers_per_switch: 1,
            seed,
            link_latency,
            watchdog_cycles: 40 * link_latency,
            ..SimConfig::default()
        };
        let mut net = Network::new(topo.clone(), router.clone(), cfg);
        let mut rng = Rng::derive(seed, 0x7AFF_1C);
        let mut wl = KernelWorkload::new(
            allreduce_rabenseifner(8, 2),
            64,
            Mapping::Linear,
            &mut rng,
        );
        let opts = RunOpts {
            max_cycles: 100_000_000,
            time_skip,
            ..RunOpts::default()
        };
        let t = Timer::start();
        let stats = net.run(&mut wl, &opts).expect("lull kernel run");
        wall += t.elapsed_secs();
        ticked += net.cycles_ticked();
        covered += stats.finish_cycle;
        delivered += stats.delivered_flits;
    }
    (wall, ticked, covered, delivered)
}

/// One message/flow scenario point on fm64 through the engine's free build
/// path (drain-bound; FCT recorded). Returns `(wall_secs, stats)`.
fn flow_point(scenario: &str, routing: &str) -> (f64, SimStats) {
    let d = FlowSpec::default();
    let fs = match scenario {
        "incast" => FlowSpec {
            scenario: "incast".into(),
            fan_in: 32,
            msg_pkts: if quick() { 4 } else { 16 },
            ..d
        },
        "hotspot" => FlowSpec {
            scenario: "hotspot".into(),
            flows: if quick() { 128 } else { 1024 },
            msg_pkts: 4,
            ..d
        },
        "closedloop" => FlowSpec {
            scenario: "closedloop".into(),
            pairs: 16,
            rounds: if quick() { 4 } else { 16 },
            ..d
        },
        "multitenant" => FlowSpec {
            scenario: "multitenant".into(),
            horizon: if quick() { 2_000 } else { 8_000 },
            ..d
        },
        other => panic!("unknown flow bench scenario {other}"),
    };
    let spec = ExperimentSpec {
        name: format!("perf-flow-{scenario}-{routing}"),
        topology: "fm64".into(),
        servers_per_switch: 8,
        routing: routing.into(),
        traffic: TrafficSpec::Flows(fs),
        seed: 7,
        max_cycles: 80_000_000,
        ..Default::default()
    };
    let mut net = tera_net::engine::build_network(&spec).expect("build");
    let mut wl = spec.build_workload(&net.topo).expect("workload");
    let opts = tera_net::engine::run_opts(&spec);
    let t = Timer::start();
    let stats = net.run(wl.as_mut(), &opts).expect("flow run");
    (t.elapsed_secs(), stats)
}

/// Compile the `RoutingTables` layer once for an instance/tier and return
/// `(wall_secs, resident_table_bytes)`.
fn table_build(
    topo: &Arc<PhysTopology>,
    svc: Option<Arc<dyn ServiceTopology>>,
    tier: TableTier,
    threads: usize,
) -> (f64, usize) {
    let t = Timer::start();
    let tables = RoutingTables::compile_with(topo.clone(), svc, tier, threads);
    let wall = t.elapsed_secs();
    (wall, std::hint::black_box(tables).table_bytes())
}

/// The tree4 group service lifted onto a Dragonfly host (the VC-less
/// deadlock-free TERA embedding the table-tier headline is measured with).
fn df_tree4(topo: &Arc<PhysTopology>) -> Arc<dyn ServiceTopology> {
    let geom = topo.kind.df_geom().expect("dragonfly host");
    let group = tera_net::service::by_name("tree4", geom.g).expect("tree4 group service");
    Arc::new(DragonflyService::new(geom, group))
}

/// One FM300 Bernoulli sweep point, fixed budget (`stop_rel_ci = None`)
/// or statistically early-terminated. Returns `(wall_secs, stats)`.
fn fm300_point(stop_rel_ci: Option<f64>, horizon: u64) -> (f64, SimStats) {
    let mut spec = bernoulli_spec("fm300", 8, "tera-path", "uniform", 0.30, horizon);
    spec.warmup = 2_000;
    spec.stop_rel_ci = stop_rel_ci;
    let mut net = tera_net::engine::build_network(&spec).expect("build");
    let mut wl = spec.build_workload(&net.topo).expect("workload");
    let opts = tera_net::engine::run_opts(&spec);
    let t = Timer::start();
    let stats = net.run(wl.as_mut(), &opts).expect("run");
    (t.elapsed_secs(), stats)
}

fn main() {
    // ---- Routing-table build + route throughput (table-driven core). ----
    println!("== routing tables: build cost + route throughput ==\n");
    {
        let t = Timer::start();
        let fm = Arc::new(topology_by_name("fm64").unwrap());
        let svc: Arc<dyn ServiceTopology> = Arc::new(HyperXService::square(64).unwrap());
        let tables = RoutingTables::compile(fm, Some(svc));
        println!(
            "build fm64 + hx2 service   {:>8.3} ms (p = {:.3})",
            t.elapsed_ms(),
            tables.main_ratio()
        );
        let t = Timer::start();
        let hx_host = Arc::new(topology_by_name("hx8x8").unwrap());
        let sub: Arc<dyn ServiceTopology> = Arc::new(HyperXService::hypercube(8).unwrap());
        let hx = HxTables::with_service(hx_host, sub);
        println!(
            "build hx8x8 per-dim tables {:>8.3} ms (sub-diameter {})",
            t.elapsed_ms(),
            hx.sub_diameter()
        );
        let t = Timer::start();
        let fm300 = Arc::new(topology_by_name("fm300").unwrap());
        let _tables300 = RoutingTables::compile(fm300, None);
        println!("build fm300 min-port only  {:>8.3} ms", t.elapsed_ms());
    }

    // ---- Hierarchical table tier: compile wall + resident bytes. ----
    // Flat vs compressed at the paper-scale points, threaded compile. The
    // acceptance headline (≥10× memory reduction at the ~1k-switch
    // Dragonfly, compile in seconds) is asserted in-bench; the full run
    // additionally builds the million-endpoint-class df2049x64x32 point
    // (131,136 switches × 8 servers/switch) compressed-only — its flat
    // tables would need ~100 GB. Rows land in BENCH_tables.json
    // (section `tables`) for the perf gate.
    println!("\n== routing-table tiers: compile wall + resident bytes ==\n");
    println!("{:<26} {:>12} {:>14}", "instance-tier", "build ms", "table bytes");
    let threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    let mut trows: Vec<String> = Vec::new();
    let mut trow = |rows: &mut Vec<String>, label: &str, wall: f64, bytes: usize| {
        println!("{label:<26} {:>12.1} {bytes:>14}", wall * 1e3);
        rows.push(format!(
            "    {{\"section\": \"tables\", \"label\": \"{label}\", \
             \"wall_secs\": {wall:.6}, \"table_bytes\": {bytes}}}"
        ));
    };
    {
        let fm300 = Arc::new(topology_by_name("fm300").unwrap());
        let svc: Arc<dyn ServiceTopology> =
            Arc::from(tera_net::service::by_name("path", fm300.n).unwrap());
        let (w, b) = table_build(&fm300, Some(svc), TableTier::Flat, threads);
        trow(&mut trows, "fm300-flat", w, b);
        let hx = Arc::new(topology_by_name("hx8x8").unwrap());
        let svc: Arc<dyn ServiceTopology> =
            Arc::from(tera_net::service::by_name("mesh2", hx.n).unwrap());
        let (w, b) = table_build(&hx, Some(svc), TableTier::Flat, threads);
        trow(&mut trows, "hx8x8-flat", w, b);
        let df1k = Arc::new(dragonfly(65, 16, 8)); // 1040 switches
        let svc = df_tree4(&df1k);
        let (w_flat, b_flat) = table_build(&df1k, Some(svc.clone()), TableTier::Flat, threads);
        trow(&mut trows, "df65x16x8-flat", w_flat, b_flat);
        let (w_comp, b_comp) = table_build(&df1k, Some(svc), TableTier::Compressed, threads);
        trow(&mut trows, "df65x16x8-compressed", w_comp, b_comp);
        assert!(
            b_flat >= 10 * b_comp,
            "compressed tier must cut table memory ≥10× at the Dragonfly-1k \
             point (flat {b_flat} B vs compressed {b_comp} B)"
        );
        assert!(
            w_comp < 10.0,
            "Dragonfly-1k compressed compile must finish in seconds (took {w_comp:.1}s)"
        );
        println!(
            "df65x16x8 compression {:.1}x, compressed compile {:.1} ms",
            b_flat as f64 / b_comp as f64,
            w_comp * 1e3
        );
        if !quick() {
            let t = Timer::start();
            let big = Arc::new(dragonfly(2049, 64, 32)); // 131,136 switches
            let topo_wall = t.elapsed_secs();
            let svc = df_tree4(&big);
            let (w, b) = table_build(&big, Some(svc), TableTier::Compressed, threads);
            trow(&mut trows, "df2049x64x32-compressed", w, b);
            println!(
                "df2049x64x32: topology {topo_wall:.2}s + tables {w:.2}s, \
                 {} switches ({} endpoints at 8 srv/sw)",
                big.n,
                big.n * 8
            );
        }
    }
    let tjson = format!(
        "{{\n  \"bench\": \"table-tiers\",\n  \"quick\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        quick(),
        trows.join(",\n")
    );
    match std::fs::write("BENCH_tables.json", &tjson) {
        Ok(()) => println!("wrote BENCH_tables.json (≥10x compression at df-1k: VERIFIED)"),
        Err(e) => println!("could not write BENCH_tables.json: {e}"),
    }

    // ---- Fault reconfiguration: degraded-rebuild latency. ----
    // Stop-the-world recompile vs incremental patch of the degraded
    // deroute overlay, at the same instance points the table-tier section
    // compiles. Four spread-out links fail at once to form the initial
    // overlay, then one more link fails: both strategies rebuild for that
    // transition, and the patch must be byte-equal to the recompile on
    // the measured artifacts (the unit-test contract, re-asserted at
    // paper scale). Two end-to-end fm64 runs with 2% of links failing
    // mid-run close the loop through the timing-wheel fault events,
    // drop/requeue and the online router swap. Rows land in
    // BENCH_faults.json (section `faults`) for the perf gate.
    println!("\n== fault reconfiguration: degraded-rebuild latency ==\n");
    println!(
        "{:<26} {:>14} {:>10} {:>8}",
        "instance", "recompile ms", "patch ms", "speedup"
    );
    let mut frows: Vec<String> = Vec::new();
    {
        let mut frow = |label: &str, wall: f64| {
            frows.push(format!(
                "    {{\"section\": \"faults\", \"label\": \"{label}\", \
                 \"wall_secs\": {wall:.6}}}"
            ));
        };
        let mut rebuild_case = |label: &str,
                                topo: &Arc<PhysTopology>,
                                svc: Option<Arc<dyn ServiceTopology>>,
                                tier: TableTier| {
            let tables = RoutingTables::compile_with(topo.clone(), svc, tier, threads);
            let mut dead = DeadSet::default();
            for i in 0..4 {
                let s = i * topo.n / 4;
                dead.fail_link(s as u32, topo.neighbor(s, 0) as u32);
            }
            let prev = tables.degraded_full(&dead);
            let (s, p) = (topo.n - 1, topo.degree(topo.n - 1) - 1);
            let nb = topo.neighbor(s, p);
            assert!(prev.dead.edge_alive(s, nb), "extra link must be fresh");
            dead.fail_link(s as u32, nb as u32);
            let t = Timer::start();
            let full = tables.degraded_full(&dead);
            let w_full = t.elapsed_secs();
            let t = Timer::start();
            let patched = tables.degraded_patch(&prev, &dead);
            let w_patch = t.elapsed_secs();
            assert!(
                full == patched,
                "incremental patch diverged from full recompile at {label}"
            );
            println!(
                "{label:<26} {:>14.2} {:>10.2} {:>7.1}x",
                w_full * 1e3,
                w_patch * 1e3,
                w_full / w_patch.max(1e-9)
            );
            frow(&format!("{label}-recompile"), w_full);
            frow(&format!("{label}-patch"), w_patch);
        };
        let fm300 = Arc::new(topology_by_name("fm300").unwrap());
        let svc: Arc<dyn ServiceTopology> =
            Arc::from(tera_net::service::by_name("path", fm300.n).unwrap());
        rebuild_case("fm300-flat", &fm300, Some(svc), TableTier::Flat);
        let hx = Arc::new(topology_by_name("hx8x8").unwrap());
        let svc: Arc<dyn ServiceTopology> =
            Arc::from(tera_net::service::by_name("mesh2", hx.n).unwrap());
        rebuild_case("hx8x8-flat", &hx, Some(svc), TableTier::Flat);
        let df1k = Arc::new(dragonfly(65, 16, 8));
        let svc = df_tree4(&df1k);
        rebuild_case("df65x16x8-compressed", &df1k, Some(svc), TableTier::Compressed);

        // End-to-end: 2% of fm64's links go down a quarter of the way in;
        // the run must keep delivering through the TERA escape under both
        // rebuild strategies, and the fault must actually have fired.
        let horizon: u64 = if quick() { 4_000 } else { 20_000 };
        for (strategy, tag) in [
            (tera_net::config::RebuildStrategy::Recompile, "recompile"),
            (tera_net::config::RebuildStrategy::Patch, "patch"),
        ] {
            let mut spec = bernoulli_spec("fm64", 8, "tera-hx2", "uniform", 0.20, horizon);
            spec.faults.link_rate = Some((2.0, horizon / 4));
            spec.faults.rebuild = strategy;
            let mut net = tera_net::engine::build_network(&spec).expect("build");
            let mut wl = spec.build_workload(&net.topo).expect("workload");
            let opts = tera_net::engine::run_opts(&spec);
            let t = Timer::start();
            let stats = net.run(wl.as_mut(), &opts).expect("faulted run");
            let wall = t.elapsed_secs();
            assert!(
                stats.delivered_packets > 0,
                "faulted fm64 run delivered nothing"
            );
            let rebuilds = net.rebuild_log().len();
            assert!(rebuilds > 0, "the 2% link-failure event never fired");
            println!(
                "fm64 2% links down ({tag}): {:.2} Mcyc/s, {rebuilds} rebuild(s), {} drops",
                horizon as f64 / wall / 1e6,
                stats.dropped_packets
            );
            frow(&format!("fm64-2pct-{tag}"), wall);
        }
    }
    let fjson = format!(
        "{{\n  \"bench\": \"fault-rebuild\",\n  \"quick\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        quick(),
        frows.join(",\n")
    );
    match std::fs::write("BENCH_faults.json", &fjson) {
        Ok(()) => println!("wrote BENCH_faults.json (patch = recompile byte-equality: VERIFIED)"),
        Err(e) => println!("could not write BENCH_faults.json: {e}"),
    }

    let mut bench = CycleBench::new();
    println!();
    println!(
        "{:<22} {:>16} {:>16} {:>8} {:>8}",
        "router@host", "scalar Mdec/s", "batched Mdec/s", "ratio", "allocs"
    );
    let iters = if quick() { 400_000 } else { 2_000_000 };
    let mut rjson = String::from("{\n  \"bench\": \"route-microbench\",\n  \"results\": [\n");
    let mut rfirst = true;
    for (host, routing) in [
        ("fm64", "min"),
        ("fm64", "valiant"),
        ("fm64", "ugal"),
        ("fm64", "omniwar"),
        ("fm64", "brinr"),
        ("fm64", "srinr"),
        ("fm64", "tera-hx2"),
        ("hx8x8", "omniwar-hx"),
        ("hx8x8", "dimwar"),
        ("hx8x8", "dor-tera"),
        ("hx8x8", "o1turn-tera"),
    ] {
        let mut dps = [0.0f64; 2];
        let mut total_allocs = 0u64;
        for (i, batched) in [false, true].into_iter().enumerate() {
            let (d, allocs) = route_throughput(host, routing, iters, batched);
            dps[i] = d;
            total_allocs += allocs;
            assert_eq!(
                allocs, 0,
                "{routing}@{host} ({}): routing allocated on the hot path",
                if batched { "batched" } else { "scalar" }
            );
            if !rfirst {
                rjson.push_str(",\n");
            }
            rfirst = false;
            rjson.push_str(&format!(
                "    {{\"section\": \"route\", \"label\": \"{routing}@{host}/{}\", \
                 \"wall_secs\": {:.6}, \"decisions\": {iters}, \
                 \"decisions_per_sec\": {d:.0}}}",
                if batched { "batched" } else { "scalar" },
                iters as f64 / d,
            ));
        }
        println!(
            "{:<22} {:>16.2} {:>16.2} {:>7.2}x {:>8}",
            format!("{routing}@{host}"),
            dps[0] / 1e6,
            dps[1] / 1e6,
            dps[1] / dps[0],
            total_allocs
        );
    }
    rjson.push_str("\n  ]\n}\n");
    match std::fs::write("BENCH_route.json", &rjson) {
        Ok(()) => println!("\nwrote BENCH_route.json (zero-allocation route path: VERIFIED)\n"),
        Err(e) => println!("\ncould not write BENCH_route.json: {e}\n"),
    }

    // ---- Idle-heavy: the active-set acceptance workload. ----
    // fm32 × 8 servers at very low uniform load: a handful of packets in
    // flight, the overwhelming majority of the 32 switches idle on any
    // given cycle. Wall time here is dominated by per-cycle fixed costs.
    println!("== idle-heavy low-load sweep (fm32 × 8 srv/sw, uniform) ==\n");
    println!("{:<8} {:>12} {:>14}", "load", "Mcycles/s", "delivered pkt/s");
    let horizon = if quick() { 60_000u64 } else { 300_000 };
    for load in [0.01, 0.02, 0.05, 0.10] {
        let spec = bernoulli_spec("fm32", 8, "tera-hx2", "uniform", load, horizon);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{load:<8} {mcps:>12.3} {pps:>14.0}");
        bench.add(
            "idle-heavy",
            &format!("load-{load}"),
            horizon as f64 / (mcps * 1e6),
            horizon as f64,
        );
    }

    // ---- Saturated end-to-end hot path (Fig-7 shape). ----
    println!("\n== saturated hot path (fm64 × 16 srv/sw, RSP 0.7) ==\n");
    println!(
        "{:<12} {:>12} {:>16}",
        "routing", "Mcycles/s", "delivered pkt/s"
    );
    let hz = if quick() { 4_000u64 } else { 12_000 };
    for r in ["min", "srinr", "tera-hx2", "ugal", "omniwar", "valiant"] {
        let spec = bernoulli_spec("fm64", 16, r, "rsp", 0.7, hz);
        let (mcps, pps) = sim_throughput(&spec);
        println!("{r:<12} {mcps:>12.3} {pps:>16.0}");
        bench.add("saturated", r, hz as f64 / (mcps * 1e6), hz as f64);
    }

    println!("\nrouting decision throughput (saturated RSP):");
    for r in ["min", "srinr", "tera-hx2", "omniwar"] {
        let d = decision_rate(r);
        println!("  {r:<12} {:>12.2} M grants/s", d / 1e6);
    }

    // ---- Shard scaling: per-shard timing wheels (parallel pop+commit). ----
    // The sharded-wheel Phase 1/6 kills the serial per-cycle bottleneck:
    // each shard pops and commits its own wheel, leaving only the
    // O(shards²) outbox pointer swap serial. This sweeps shards 1..8 on
    // the two instances the paper cares about — saturated FM300 and the
    // palmtree df65x16x8 — plus the `--global-wheel` A/B baseline at 4
    // shards (same partition, one wheel: the pre-sharded-wheel Phase 1/6).
    // Delivered-flit equality vs the serial run is asserted for every
    // point, and on full runs with ≥ 4 cores the sharded wheel must beat
    // the global-wheel baseline by ≥ 1.5× at 4 shards on FM300. Rows land
    // in BENCH_shards.json (section `shards`) for the perf gate — and only
    // there, so the section is gated once.
    println!("\n== shard scaling (per-shard wheels vs --global-wheel) ==\n");
    println!(
        "{:<28} {:>7} {:>12} {:>9} {:>11}",
        "instance", "shards", "Mcycles/s", "speedup", "efficiency"
    );
    let mut srows: Vec<String> = Vec::new();
    let mut srow = |label: &str, wall: f64, hz: u64, mcps: f64, speedup: f64| {
        srows.push(format!(
            "    {{\"section\": \"shards\", \"label\": \"{label}\", \
             \"wall_secs\": {wall:.6}, \"cycles\": {hz}, \
             \"mcycles_per_sec\": {mcps:.4}, \"speedup_vs_serial\": {speedup:.3}}}"
        ));
    };
    let can_assert_speedup =
        !quick() && std::thread::available_parallelism().map_or(1, |n| n.get()) >= 4;
    for (tag, topo, spc, routing, pattern, load, hz) in [
        (
            "fm300-rsp0.7",
            "fm300",
            8usize,
            "tera-path",
            "rsp",
            0.7,
            if quick() { 400u64 } else { 1_600 },
        ),
        (
            "df65x16x8-uni0.4",
            "df65x16x8",
            4,
            "tera-path",
            "uniform",
            0.4,
            if quick() { 200u64 } else { 800 },
        ),
    ] {
        let mut base_mcps = 0.0f64;
        let mut base_flits = 0u64;
        let mut mcps_at_4 = 0.0f64;
        for shards in [1usize, 2, 4, 8] {
            let mut spec = bernoulli_spec(topo, spc, routing, pattern, load, hz);
            spec.shards = shards;
            let (mcps, flits) = sharded_throughput(&spec);
            if shards == 1 {
                base_mcps = mcps;
                base_flits = flits;
            } else {
                assert_eq!(
                    flits, base_flits,
                    "{tag}@{shards} shards: determinism violated vs serial run"
                );
            }
            if shards == 4 {
                mcps_at_4 = mcps;
            }
            let speedup = mcps / base_mcps;
            println!(
                "{tag:<28} {shards:>7} {mcps:>12.3} {speedup:>8.2}x {:>10.0}%",
                100.0 * speedup / shards as f64
            );
            srow(
                &format!("{tag}-s{shards}"),
                hz as f64 / (mcps * 1e6),
                hz,
                mcps,
                speedup,
            );
        }
        // The A/B baseline: same 4-shard partition, one global wheel —
        // Phase 1 pops and the commit fan-in re-serialize on shard 0.
        let mut gspec = bernoulli_spec(topo, spc, routing, pattern, load, hz);
        gspec.shards = 4;
        gspec.global_wheel = true;
        let (gmcps, gflits) = sharded_throughput(&gspec);
        assert_eq!(
            gflits, base_flits,
            "{tag}: --global-wheel diverged from the per-shard-wheel run"
        );
        let wheel_speedup = mcps_at_4 / gmcps;
        println!(
            "{:<28} {:>7} {gmcps:>12.3} {:>8.2}x {:>11}",
            format!("{tag} global-wheel"),
            4,
            gmcps / base_mcps,
            "-"
        );
        println!("  sharded wheel vs --global-wheel at 4 shards: {wheel_speedup:.2}x");
        srow(
            &format!("{tag}-global-wheel-s4"),
            hz as f64 / (gmcps * 1e6),
            hz,
            gmcps,
            gmcps / base_mcps,
        );
        if can_assert_speedup && topo == "fm300" {
            assert!(
                wheel_speedup >= 1.5,
                "sharded wheel below 1.5x over --global-wheel at 4 shards on {tag} \
                 ({wheel_speedup:.2}x)"
            );
        }
    }
    let artifact = format!(
        "{{\n  \"bench\": \"shard-scaling\",\n  \"quick\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        quick(),
        srows.join(",\n")
    );
    match std::fs::write("BENCH_shards.json", &artifact) {
        Ok(()) => println!("\nwrote BENCH_shards.json (sharded-wheel determinism: VERIFIED)"),
        Err(e) => println!("\ncould not write BENCH_shards.json: {e}"),
    }

    // ---- Batched hot path: scalar vs batched compute, saturated FM300. ----
    // The gather/score/commit restructure of the compute phase
    // (`SimConfig::batched`, DESIGN.md "Batched hot path") is a pure
    // wall-clock knob: delivered flits are asserted equal, and the
    // measured A/B is the optimization's acceptance number on the paper's
    // FM300-class instance at saturating load.
    println!("\n== batched hot path (fm300 × 8 srv/sw, RSP 0.7, serial) ==\n");
    println!("{:<10} {:>12}", "mode", "Mcycles/s");
    let bhz = if quick() { 600u64 } else { 1_800 };
    let mut ab_mcps = [0.0f64; 2];
    let mut ab_flits = [0u64; 2];
    for (i, batched) in [false, true].into_iter().enumerate() {
        let mut spec = bernoulli_spec("fm300", 8, "tera-path", "rsp", 0.7, bhz);
        spec.batched_compute = batched;
        let (mcps, flits) = sharded_throughput(&spec);
        ab_mcps[i] = mcps;
        ab_flits[i] = flits;
        let mode = if batched { "batched" } else { "scalar" };
        println!("{mode:<10} {mcps:>12.3}");
        bench.add("batched-fm300", mode, bhz as f64 / (mcps * 1e6), bhz as f64);
    }
    assert_eq!(
        ab_flits[0], ab_flits[1],
        "batched compute diverged from the scalar reference on fm300"
    );
    println!(
        "batched speedup {:.2}x (scalar bit-identity: VERIFIED)",
        ab_mcps[1] / ab_mcps[0]
    );

    // ---- Adaptive time advance: lull-heavy fm64 kernel. ----
    // A sparse 8-rank allreduce across a 16384-cycle wire: between bursts
    // of serialization the whole network is dead — exactly the regime the
    // next-event fast path targets. Bit-identity vs fixed-tick is asserted
    // (delivered flits and covered cycles), the deterministic
    // ticked/covered ratio is gated at < 0.5, and the wall-clock speedup
    // is reported in BENCH_adaptive.json.
    println!("\n== adaptive time advance (fm64 allreduce, link_latency 16384) ==\n");
    let link_latency = 16_384u64;
    let reps = if quick() { 2 } else { 8 };
    let (fixed_wall, fixed_ticked, fixed_covered, fixed_flits) =
        lull_kernel_run(false, link_latency, reps);
    let (skip_wall, skip_ticked, skip_covered, skip_flits) =
        lull_kernel_run(true, link_latency, reps);
    assert_eq!(
        fixed_flits, skip_flits,
        "adaptive time advance changed delivered flits"
    );
    assert_eq!(
        fixed_covered, skip_covered,
        "adaptive time advance changed the completion cycle"
    );
    assert_eq!(
        fixed_ticked, fixed_covered,
        "fixed-tick run must simulate every covered cycle"
    );
    let tick_ratio = skip_ticked as f64 / skip_covered as f64;
    assert!(
        tick_ratio < 0.5,
        "lull-heavy kernel must skip most cycles (ticked/covered = {tick_ratio:.3})"
    );
    let kernel_speedup = fixed_wall / skip_wall;
    println!("{:<22} {:>14} {:>14}", "", "fixed-tick", "adaptive");
    println!(
        "{:<22} {:>14.4} {:>14.4}",
        "wall secs", fixed_wall, skip_wall
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "cycles ticked", fixed_ticked, skip_ticked
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "cycles covered", fixed_covered, skip_covered
    );
    println!(
        "speedup {kernel_speedup:.2}x, ticked/covered {tick_ratio:.4} \
         (delivered-flit equality: VERIFIED)"
    );
    bench.add("lull-kernel", "fixed-tick", fixed_wall, fixed_covered as f64);
    bench.add("lull-kernel", "adaptive", skip_wall, skip_covered as f64);

    // ---- Statistical early termination: FM300 sweep point. ----
    println!("\n== statistical early termination (fm300 × 8 srv/sw, uniform 0.30) ==\n");
    let ci_horizon = if quick() { 10_000u64 } else { 40_000 };
    let ci_target = 0.05f64;
    let (fx_wall, fx_stats) = fm300_point(None, ci_horizon);
    let (ci_wall, ci_stats) = fm300_point(Some(ci_target), ci_horizon);
    let achieved = ci_stats.achieved_rel_ci.unwrap_or(f64::NAN);
    let thr_fixed = fx_stats.accepted_throughput();
    let thr_ci = ci_stats.accepted_throughput();
    println!(
        "fixed budget : {} cycles, {fx_wall:.3}s, throughput {thr_fixed:.4}",
        fx_stats.finish_cycle
    );
    println!(
        "early stop   : {} cycles, {ci_wall:.3}s, throughput {thr_ci:.4}, \
         achieved rel CI {achieved:.4} (target {ci_target})",
        ci_stats.finish_cycle
    );
    bench.add(
        "early-termination",
        "fixed",
        fx_wall,
        fx_stats.finish_cycle as f64,
    );
    bench.add(
        "early-termination",
        "adaptive",
        ci_wall,
        ci_stats.finish_cycle as f64,
    );

    let adaptive_json = format!(
        "{{\n  \"bench\": \"adaptive-simulation-length\",\n  \
         \"kernel_section\": {{\n    \"topology\": \"fm64\", \"kernel\": \"allreduce-8rank\", \
         \"link_latency\": {link_latency}, \"reps\": {reps},\n    \
         \"fixed_wall_secs\": {fixed_wall:.6}, \"adaptive_wall_secs\": {skip_wall:.6}, \
         \"wall_speedup\": {kernel_speedup:.3},\n    \
         \"cycles_ticked\": {skip_ticked}, \"cycles_covered\": {skip_covered}, \
         \"ticked_over_covered\": {tick_ratio:.5},\n    \
         \"delivered_flits_equal\": {}\n  }},\n  \
         \"early_termination\": {{\n    \"topology\": \"fm300\", \"load\": 0.30, \
         \"horizon\": {ci_horizon}, \"rel_ci_target\": {ci_target},\n    \
         \"fixed_cycles\": {}, \"adaptive_cycles\": {}, \
         \"fixed_wall_secs\": {fx_wall:.6}, \"adaptive_wall_secs\": {ci_wall:.6},\n    \
         \"achieved_rel_ci\": {achieved:.5}, \
         \"throughput_fixed\": {thr_fixed:.5}, \"throughput_adaptive\": {thr_ci:.5}\n  }}\n}}\n",
        fixed_flits == skip_flits,
        fx_stats.finish_cycle,
        ci_stats.finish_cycle,
    );
    match std::fs::write("BENCH_adaptive.json", &adaptive_json) {
        Ok(()) => println!("\nwrote BENCH_adaptive.json (adaptive determinism: VERIFIED)"),
        Err(e) => println!("\ncould not write BENCH_adaptive.json: {e}"),
    }

    // ---- Message/flow workloads: the FCT pipeline end-to-end. ----
    // Every scenario of the flow layer (incast fan-in, hotspot skew,
    // closed-loop request/response, multi-tenant mix) under the paper's
    // VC-less escape router and a link-ordering baseline. Asserts the
    // completion invariant (a drained run completes every offered message)
    // and emits BENCH_flows.json as the flow-path perf-trajectory artifact
    // the CI regression gate diffs.
    println!("\n== message/flow workloads (fm64 × 8 srv/sw) ==\n");
    println!(
        "{:<14} {:<10} {:>7} {:>9} {:>9} {:>9} {:>12}",
        "scenario", "routing", "msgs", "fct p50", "fct p99", "slow p99", "msgs/s"
    );
    let mut fjson = String::from(
        "{\n  \"bench\": \"flow-workloads\",\n  \"topology\": \"fm64\",\n  \"results\": [\n",
    );
    let mut ffirst = true;
    for scenario in ["incast", "hotspot", "closedloop", "multitenant"] {
        for routing in ["tera-hx2", "srinr"] {
            let (wall, stats) = flow_point(scenario, routing);
            let f = stats.fct.as_ref().expect("flow run reports FCT");
            assert!(f.completed > 0, "{scenario}/{routing}: no messages completed");
            assert_eq!(
                f.completed, f.offered,
                "{scenario}/{routing}: drained run must complete every message"
            );
            let mps = f.completed as f64 / wall.max(1e-9);
            println!(
                "{scenario:<14} {routing:<10} {:>7} {:>9} {:>9} {:>9.2} {mps:>12.0}",
                f.completed,
                f.fct_percentile(50.0),
                f.fct_percentile(99.0),
                f.slowdown_percentile(99.0),
            );
            // Flow walls land ONLY in BENCH_flows.json (folded into the
            // "flows" section by the CI gate) — recording them into
            // BENCH_cycles.json too would gate the same number twice.
            if !ffirst {
                fjson.push_str(",\n");
            }
            ffirst = false;
            fjson.push_str(&format!(
                "    {{\"scenario\": \"{scenario}\", \"routing\": \"{routing}\", \
                 \"wall_secs\": {wall:.6}, \"messages\": {}, \"fct_p50\": {}, \
                 \"fct_p99\": {}, \"slowdown_p99\": {:.3}, \
                 \"messages_per_sec\": {mps:.0}}}",
                f.completed,
                f.fct_percentile(50.0),
                f.fct_percentile(99.0),
                f.slowdown_percentile(99.0),
            ));
        }
    }
    fjson.push_str("\n  ]\n}\n");
    match std::fs::write("BENCH_flows.json", &fjson) {
        Ok(()) => println!("\nwrote BENCH_flows.json (message completion: VERIFIED)"),
        Err(e) => println!("\ncould not write BENCH_flows.json: {e}"),
    }

    bench.write();

    // PJRT batched scorer (decision path through the artifact).
    if cfg!(feature = "pjrt") && std::path::Path::new("artifacts/tera_score.hlo.txt").exists() {
        use tera_net::runtime::{Engine as PjrtEngine, ScoreBatch, TeraScorer};
        let engine = PjrtEngine::cpu().unwrap();
        let scorer = TeraScorer::load(&engine).unwrap();
        let mut b = ScoreBatch::zeros(TeraScorer::BATCH, TeraScorer::PORTS, 54.0);
        for i in 0..b.occ.len() {
            b.occ[i] = (i % 97) as f32;
            b.valid[i] = 1.0;
            b.direct[i] = f32::from(i % 63 == 0);
        }
        let t = Timer::start();
        let iters = 500;
        for _ in 0..iters {
            scorer.score(&b).unwrap();
        }
        let per_call_ms = t.elapsed_ms() / iters as f64;
        println!(
            "\npjrt tera_score: {per_call_ms:.3} ms / 64-switch batch \
             ({:.2} M decisions/s)",
            (TeraScorer::BATCH as f64 / (per_call_ms / 1e3)) / 1e6
        );
    } else {
        println!("\n(pjrt scorer skipped: needs --features pjrt and `make artifacts`)");
    }
}
